//! Tree-based pre-eviction (Ganguly et al., ISCA'19): the inverse of the
//! tree prefetcher's threshold heuristic. Whenever a non-leaf node of a
//! chunk tree falls **below 50% occupancy**, the remaining valid 64 KB
//! leaves under it are scheduled for pre-eviction — the intuition being
//! that a draining region will not be re-referenced soon.
//!
//! Used by the ablation benches (`policies` bench) and available to the
//! experiment harness as an alternative evictor; falls back to LRU order
//! when the pre-eviction queue is empty.

use std::collections::{HashMap, VecDeque};

use crate::config::{BBS_PER_CHUNK, PAGES_PER_BB};
use crate::sim::{DeviceMemory, Page};
use crate::trace::Access;

use super::lru::Lru;
use super::Evictor;

const PAGES_PER_CHUNK: u64 = PAGES_PER_BB * BBS_PER_CHUNK;
const NODES: usize = 2 * BBS_PER_CHUNK as usize;

#[derive(Debug)]
pub struct TreeEvict {
    valid: HashMap<u64, [u16; NODES]>, // chunk -> heap counters
    resident: HashMap<Page, ()>,
    /// pages scheduled for pre-eviction (drained by select_victim)
    queue: VecDeque<Page>,
    fallback: Lru,
}

impl TreeEvict {
    pub fn new() -> TreeEvict {
        TreeEvict {
            valid: HashMap::new(),
            resident: HashMap::new(),
            queue: VecDeque::new(),
            fallback: Lru::new(),
        }
    }

    fn leaf(page: Page) -> (u64, usize) {
        let chunk = page / PAGES_PER_CHUNK;
        let bb = (page % PAGES_PER_CHUNK) / PAGES_PER_BB;
        (chunk, BBS_PER_CHUNK as usize + bb as usize)
    }

    fn node_capacity(i: usize) -> u64 {
        let depth = (usize::BITS - 1 - i.leading_zeros()) as u64;
        PAGES_PER_CHUNK >> depth
    }

    /// After an eviction, check the victim's ancestors: any node that
    /// dropped below 50% schedules its remaining resident pages.
    fn schedule_drain(&mut self, page: Page) {
        let (chunk, mut i) = Self::leaf(page);
        let counters = match self.valid.get(&chunk) {
            Some(c) => *c,
            None => return,
        };
        i /= 2; // start at the first non-leaf ancestor
        while i >= 1 {
            let cap = Self::node_capacity(i);
            let v = counters[i] as u64;
            if v > 0 && v * 2 < cap {
                // collect resident pages under node i
                let depth = (usize::BITS - 1 - i.leading_zeros()) as usize;
                let leaves_under = BBS_PER_CHUNK as usize >> depth;
                let first_leaf = (i << (5 - depth)) - BBS_PER_CHUNK as usize;
                for leaf in first_leaf..first_leaf + leaves_under {
                    let base = chunk * PAGES_PER_CHUNK + leaf as u64 * PAGES_PER_BB;
                    for p in base..base + PAGES_PER_BB {
                        if self.resident.contains_key(&p) {
                            self.queue.push_back(p);
                        }
                    }
                }
                break; // one draining node per eviction event
            }
            i /= 2;
        }
    }

    fn adjust(&mut self, page: Page, delta: i32) {
        let (chunk, mut i) = Self::leaf(page);
        let counters = self.valid.entry(chunk).or_insert([0; NODES]);
        while i >= 1 {
            let v = counters[i] as i32 + delta;
            debug_assert!(v >= 0);
            counters[i] = v as u16;
            i /= 2;
        }
    }
}

impl Default for TreeEvict {
    fn default() -> Self {
        TreeEvict::new()
    }
}

impl Evictor for TreeEvict {
    fn name(&self) -> String {
        "TreeEvict".into()
    }

    fn on_access(&mut self, acc: &Access, resident: bool) {
        self.fallback.on_access(acc, resident);
    }

    fn on_migrate(&mut self, page: Page, via_prefetch: bool) {
        if self.resident.insert(page, ()).is_none() {
            self.adjust(page, 1);
        }
        self.fallback.on_migrate(page, via_prefetch);
    }

    fn on_evict(&mut self, page: Page) {
        if self.resident.remove(&page).is_some() {
            self.adjust(page, -1);
            self.schedule_drain(page);
        }
        self.fallback.on_evict(page);
    }

    fn select_victim(&mut self, mem: &DeviceMemory) -> Option<Page> {
        while let Some(p) = self.queue.pop_front() {
            if self.resident.contains_key(&p) {
                return Some(p);
            }
        }
        self.fallback.select_victim(mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drains_below_half_occupancy() {
        let mem = DeviceMemory::new(1024);
        let mut t = TreeEvict::new();
        // fill bb 0 (16 pages): parent node (cap 32) at exactly 50%
        for p in 0..16 {
            t.on_migrate(p, false);
        }
        // evict one page: parent drops below 50% => remaining 15 pages of
        // the node get scheduled
        t.on_evict(3);
        let v = t.select_victim(&mem);
        assert!(v.is_some());
        assert!(v.unwrap() < 16, "drain victim from the draining node");
    }

    #[test]
    fn falls_back_to_lru_when_queue_empty() {
        let mem = DeviceMemory::new(1024);
        let mut t = TreeEvict::new();
        // two full chunks' worth keeps every node >= 50%
        for p in 0..512 {
            t.on_migrate(p, false);
        }
        assert_eq!(t.select_victim(&mem), Some(0), "LRU order");
    }

    #[test]
    fn stale_drain_entries_skipped() {
        let mem = DeviceMemory::new(1024);
        let mut t = TreeEvict::new();
        for p in 0..16 {
            t.on_migrate(p, false);
        }
        t.on_evict(3);
        // externally evict everything the drain queued
        for p in 0..16 {
            t.on_evict(p);
        }
        assert_eq!(t.select_victim(&mem), None);
    }
}
