//! Random eviction (Zheng et al. HPCA'16 comparison point): a uniformly
//! random resident page, irrespective of recency. Sometimes beats LRU on
//! thrashing patterns precisely because it is recency-blind. Reactive
//! only — it never emits `pre_evict` directives (randomly draining
//! frames ahead of pressure would be noise, not policy).

use std::collections::HashMap;

use crate::sim::{DeviceMemory, Page};
use crate::util::rng::Rng;

use super::Evictor;

#[derive(Debug)]
pub struct RandomEvict {
    rng: Rng,
    /// swap-remove vector + index map for O(1) membership updates
    pages: Vec<Page>,
    index: HashMap<Page, usize>,
}

impl RandomEvict {
    pub fn new(seed: u64) -> RandomEvict {
        RandomEvict {
            rng: Rng::new(seed),
            pages: Vec::new(),
            index: HashMap::new(),
        }
    }
}

impl Evictor for RandomEvict {
    fn name(&self) -> String {
        "Random".into()
    }

    fn on_migrate(&mut self, page: Page, _via_prefetch: bool) {
        if !self.index.contains_key(&page) {
            self.index.insert(page, self.pages.len());
            self.pages.push(page);
        }
    }

    fn on_evict(&mut self, page: Page) {
        if let Some(i) = self.index.remove(&page) {
            let last = self.pages.pop().expect("non-empty");
            if i < self.pages.len() {
                self.pages[i] = last;
                self.index.insert(last, i);
            }
        }
    }

    fn select_victim(&mut self, _mem: &DeviceMemory) -> Option<Page> {
        if self.pages.is_empty() {
            None
        } else {
            Some(*self.rng.choose(&self.pages))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_membership() {
        let mem = DeviceMemory::new(8);
        let mut r = RandomEvict::new(1);
        for p in 0..5 {
            r.on_migrate(p, false);
        }
        r.on_evict(2);
        for _ in 0..100 {
            let v = r.select_victim(&mem).unwrap();
            assert_ne!(v, 2);
            assert!(v < 5);
        }
    }

    #[test]
    fn empty_returns_none() {
        let mem = DeviceMemory::new(8);
        let mut r = RandomEvict::new(1);
        assert_eq!(r.select_victim(&mem), None);
        r.on_migrate(1, false);
        r.on_evict(1);
        assert_eq!(r.select_victim(&mem), None);
    }

    #[test]
    fn covers_all_resident_pages() {
        let mem = DeviceMemory::new(8);
        let mut r = RandomEvict::new(7);
        for p in 0..4 {
            r.on_migrate(p, false);
        }
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[r.select_victim(&mem).unwrap() as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
