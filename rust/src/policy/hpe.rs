//! HPE — Hierarchical Page Eviction (Yu et al., TCAD'19).
//!
//! HPE manages a **page set chain** of three partitions (new / middle /
//! old) rotated every interval (64 page faults), and classifies the
//! application's access pattern from **per-basic-block touched-page
//! counters** to pick an eviction strategy:
//!
//! * *regular* (dense blocks, LRU-friendly): evict old → middle → new,
//!   oldest-inserted first — LRU-with-generations;
//! * *irregular / thrashing* (sparse blocks): evict from the NEW end
//!   first, protecting the aged warm set — the anti-thrash move plain
//!   LRU cannot make.
//!
//! The classifier is the policy's Achilles heel the paper exploits in
//! Table II: data prefetching inflates the per-block counters (prefetched
//! pages count as touched blocks), flipping the classification to
//! "regular" and letting a streaming burst flush the warm set —
//! "Tree.+HPE" loses by orders of magnitude while "Demand.+HPE" is
//! near-optimal. We reproduce the mechanism, not just the outcome.
//!
//! HPE is a reactive [`Evictor`] (pulled at `VictimNeeded` decisions;
//! no `pre_evict` directives) — its chain rotation rides the
//! composite's `Interval` event, exactly as it rode `on_interval`
//! before the decision-API redesign.

use std::collections::{HashMap, VecDeque};

use crate::config::PAGES_PER_BB;
use crate::sim::{DeviceMemory, Page};
use crate::trace::Access;

use super::Evictor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Regular,
    Irregular,
}

#[derive(Debug)]
pub struct Hpe {
    /// page set chain: [new, middle, old] insertion queues
    new: VecDeque<Page>,
    middle: VecDeque<Page>,
    old: VecDeque<Page>,
    /// residency mirror; value = touches since migration
    touches: HashMap<Page, u32>,
    /// per-basic-block distinct-page-touch counters (the classifier input)
    bb_pages: HashMap<u64, u16>,
    mode: Mode,
    /// density threshold (of PAGES_PER_BB) above which a block is "dense"
    dense_threshold: u16,
    /// classified every interval from the accumulated block stats
    intervals: u64,
}

impl Hpe {
    pub fn new() -> Hpe {
        Hpe {
            new: VecDeque::new(),
            middle: VecDeque::new(),
            old: VecDeque::new(),
            touches: HashMap::new(),
            bb_pages: HashMap::new(),
            mode: Mode::Regular,
            dense_threshold: (PAGES_PER_BB as u16) * 3 / 4, // 12 of 16
            intervals: 0,
        }
    }

    pub fn mode_name(&self) -> &'static str {
        match self.mode {
            Mode::Regular => "regular",
            Mode::Irregular => "irregular",
        }
    }

    fn classify(&mut self) {
        // too few active blocks to classify: keep the previous mode
        if self.bb_pages.len() < 4 {
            self.bb_pages.clear();
            return;
        }
        let dense = self
            .bb_pages
            .values()
            .filter(|&&c| c >= self.dense_threshold)
            .count();
        let frac = dense as f64 / self.bb_pages.len() as f64;
        // Mostly-dense blocks => linear/regular access; sparse => irregular.
        self.mode = if frac >= 0.5 { Mode::Regular } else { Mode::Irregular };
        // window the stats so phase changes re-classify
        self.bb_pages.clear();
    }

    /// Pop the first still-resident page from a queue (lazy cleanup).
    fn pop_resident(
        queue: &mut VecDeque<Page>,
        touches: &HashMap<Page, u32>,
        from_back: bool,
    ) -> Option<Page> {
        while let Some(p) = if from_back { queue.pop_back() } else { queue.pop_front() } {
            if touches.contains_key(&p) {
                return Some(p);
            }
        }
        None
    }
}

impl Default for Hpe {
    fn default() -> Self {
        Hpe::new()
    }
}

impl Evictor for Hpe {
    fn name(&self) -> String {
        "HPE".into()
    }

    fn on_access(&mut self, acc: &Access, resident: bool) {
        if resident {
            if let Some(t) = self.touches.get_mut(&acc.page) {
                *t = t.saturating_add(1);
            }
        }
    }

    fn on_migrate(&mut self, page: Page, _via_prefetch: bool) {
        if self.touches.insert(page, 0).is_none() {
            self.new.push_back(page);
        }
        // classifier input: a migration marks this page "touched" in its
        // block — prefetched pages inflate this, by (faithful) design.
        let bb = page / PAGES_PER_BB;
        let c = self.bb_pages.entry(bb).or_insert(0);
        *c = c.saturating_add(1);
    }

    fn on_evict(&mut self, page: Page) {
        // queues are cleaned lazily at pop time
        self.touches.remove(&page);
    }

    fn on_interval(&mut self) {
        self.intervals += 1;
        // age the chain: middle -> old, new -> middle
        let aged: Vec<Page> = self.middle.drain(..).collect();
        self.old.extend(aged);
        let fresh: Vec<Page> = self.new.drain(..).collect();
        self.middle.extend(fresh);
        self.classify();
    }

    fn select_victim(&mut self, _mem: &DeviceMemory) -> Option<Page> {
        match self.mode {
            Mode::Regular => {
                // oldest partition, oldest insertion first
                Self::pop_resident(&mut self.old, &self.touches, false)
                    .or_else(|| {
                        Self::pop_resident(&mut self.middle, &self.touches, false)
                    })
                    .or_else(|| {
                        Self::pop_resident(&mut self.new, &self.touches, false)
                    })
            }
            Mode::Irregular => {
                // protect the warm set: sacrifice the newest pages first
                Self::pop_resident(&mut self.new, &self.touches, true)
                    .or_else(|| {
                        Self::pop_resident(&mut self.middle, &self.touches, true)
                    })
                    .or_else(|| {
                        Self::pop_resident(&mut self.old, &self.touches, true)
                    })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::belady::count_misses;
    use crate::policy::lru::Lru;

    fn acc(page: Page) -> Access {
        Access { page, pc: 0, tb: 0, kernel: 0, inst_gap: 0, is_write: false }
    }

    #[test]
    fn chain_rotation_moves_partitions() {
        let mut h = Hpe::new();
        h.on_migrate(1, false);
        h.on_interval();
        h.on_migrate(2, false);
        h.on_interval();
        h.on_migrate(3, false);
        // 1 is old, 2 middle, 3 new
        assert_eq!(h.old.front(), Some(&1));
        assert_eq!(h.middle.front(), Some(&2));
        assert_eq!(h.new.front(), Some(&3));
    }

    #[test]
    fn regular_mode_evicts_oldest_partition_first() {
        let mem = DeviceMemory::new(16);
        let mut h = Hpe::new();
        h.on_migrate(1, false);
        h.on_interval();
        h.on_interval(); // 1 -> old
        h.on_migrate(2, false);
        assert_eq!(h.mode, Mode::Regular);
        assert_eq!(h.select_victim(&mem), Some(1));
    }

    #[test]
    fn sparse_blocks_flip_to_irregular_and_protect_old() {
        let mem = DeviceMemory::new(64);
        let mut h = Hpe::new();
        // sparse touches: one page per distinct basic block
        for bb in 0..8u64 {
            h.on_migrate(bb * PAGES_PER_BB, false);
        }
        h.on_interval(); // classify: sparse -> irregular; pages -> middle
        assert_eq!(h.mode, Mode::Irregular);
        h.on_migrate(999 * PAGES_PER_BB, false); // lands in new
        // irregular mode sacrifices the NEW page, protecting the aged set
        assert_eq!(h.select_victim(&mem), Some(999 * PAGES_PER_BB));
    }

    #[test]
    fn dense_blocks_classify_regular() {
        let mut h = Hpe::new();
        for p in 0..PAGES_PER_BB * 2 {
            h.on_migrate(p, false); // two fully dense blocks
        }
        h.on_interval();
        assert_eq!(h.mode, Mode::Regular);
    }

    #[test]
    fn stale_queue_entries_skipped() {
        let mem = DeviceMemory::new(16);
        let mut h = Hpe::new();
        h.on_migrate(1, false);
        h.on_migrate(2, false);
        h.on_evict(1);
        assert_eq!(h.select_victim(&mem), Some(2));
    }

    #[test]
    fn beats_lru_on_thrash_cycle() {
        // cyclic access over capacity+k pages: the LRU pathology.
        // HPE (irregular mode) keeps a warm subset resident and must miss
        // strictly less than LRU's 100% miss rate.
        let seq: Vec<Page> = (0..8u64)
            .map(|p| p * PAGES_PER_BB) // sparse => irregular
            .cycle()
            .take(400)
            .collect();
        let mut h = Hpe::new();
        // prime the classifier with the sparse pattern
        for &p in seq.iter().take(8) {
            h.on_migrate(p, false);
        }
        h.on_interval();
        let hpe = count_misses(&seq, 6, &mut h);
        let lru = count_misses(&seq, 6, &mut Lru::new());
        assert!(hpe < lru, "HPE {hpe} vs LRU {lru}");
    }
}
