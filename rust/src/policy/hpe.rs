//! HPE — Hierarchical Page Eviction (Yu et al., TCAD'19).
//!
//! HPE manages a **page set chain** of three partitions (new / middle /
//! old) rotated every interval (64 page faults), and classifies the
//! application's access pattern from **per-basic-block touched-page
//! counters** to pick an eviction strategy:
//!
//! * *regular* (dense blocks, LRU-friendly): evict old → middle → new,
//!   oldest-inserted first — LRU-with-generations;
//! * *irregular / thrashing* (sparse blocks): evict from the NEW end
//!   first, protecting the aged warm set — the anti-thrash move plain
//!   LRU cannot make.
//!
//! The classifier is the policy's Achilles heel the paper exploits in
//! Table II: data prefetching inflates the per-block counters (prefetched
//! pages count as touched blocks), flipping the classification to
//! "regular" and letting a streaming burst flush the warm set —
//! "Tree.+HPE" loses by orders of magnitude while "Demand.+HPE" is
//! near-optimal. We reproduce the mechanism, not just the outcome.
//!
//! [`Hpe::new`] is the faithful reactive [`Evictor`] (pulled at
//! `VictimNeeded` decisions; no `pre_evict` directives) — its chain
//! rotation rides the composite's `Interval` event, exactly as it rode
//! `on_interval` before the decision-API redesign.
//!
//! [`Hpe::proactive`] adds the directive-API extension the chain makes
//! natural: pages aging out of the *middle* partition are exactly the
//! pages HPE itself would evict first in regular mode, so instead of
//! waiting for memory pressure to pull them one `VictimNeeded` at a
//! time, the proactive variant queues them for **background drain**
//! (`pre_evict` directives on the slack-scheduled transfer queue).
//! Drain happens only while the classifier says *regular* — in
//! irregular/thrashing phases the old partition is the protected warm
//! set and draining it would be exactly the pathology HPE exists to
//! avoid — and a still-warm candidate (touched since aging) is dropped
//! rather than drained. Victim selection is untouched, so the variant
//! degrades to reactive HPE whenever the drain is empty.

use std::collections::{HashMap, VecDeque};

use crate::config::PAGES_PER_BB;
use crate::policy::MemView;
use crate::sim::{DeviceMemory, Page};
use crate::trace::Access;

use super::Evictor;

/// A drain candidate touched more than this many times since migration
/// is considered warm and is dropped from the background drain (it can
/// still be picked reactively at `VictimNeeded` time).
const DRAIN_TOUCH_GUARD: u32 = 2;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Regular,
    Irregular,
}

#[derive(Debug)]
pub struct Hpe {
    /// page set chain: [new, middle, old] insertion queues
    new: VecDeque<Page>,
    middle: VecDeque<Page>,
    old: VecDeque<Page>,
    /// residency mirror; value = touches since migration
    touches: HashMap<Page, u32>,
    /// per-basic-block distinct-page-touch counters (the classifier input)
    bb_pages: HashMap<u64, u16>,
    mode: Mode,
    /// density threshold (of PAGES_PER_BB) above which a block is "dense"
    dense_threshold: u16,
    /// classified every interval from the accumulated block stats
    intervals: u64,
    /// emit background-drain `pre_evict` directives ([`Hpe::proactive`])
    proactive: bool,
    /// pages aged out of `middle` during a regular phase, queued for
    /// background drain (oldest first)
    drain: VecDeque<Page>,
}

impl Hpe {
    pub fn new() -> Hpe {
        Hpe {
            new: VecDeque::new(),
            middle: VecDeque::new(),
            old: VecDeque::new(),
            touches: HashMap::new(),
            bb_pages: HashMap::new(),
            mode: Mode::Regular,
            dense_threshold: (PAGES_PER_BB as u16) * 3 / 4, // 12 of 16
            intervals: 0,
            proactive: false,
            drain: VecDeque::new(),
        }
    }

    /// The pre-evict-aware variant (see the module docs): chain
    /// rotation additionally queues regular-phase `old` arrivals for
    /// background drain via `pre_evict` directives.
    pub fn proactive() -> Hpe {
        Hpe { proactive: true, ..Hpe::new() }
    }

    pub fn is_proactive(&self) -> bool {
        self.proactive
    }

    pub fn mode_name(&self) -> &'static str {
        match self.mode {
            Mode::Regular => "regular",
            Mode::Irregular => "irregular",
        }
    }

    fn classify(&mut self) {
        // too few active blocks to classify: keep the previous mode
        if self.bb_pages.len() < 4 {
            self.bb_pages.clear();
            return;
        }
        let dense = self
            .bb_pages // lint: sorted — counting is order-independent
            .values()
            .filter(|&&c| c >= self.dense_threshold)
            .count();
        let frac = dense as f64 / self.bb_pages.len() as f64;
        // Mostly-dense blocks => linear/regular access; sparse => irregular.
        self.mode = if frac >= 0.5 { Mode::Regular } else { Mode::Irregular };
        // window the stats so phase changes re-classify
        self.bb_pages.clear();
    }

    /// Pop the first still-resident page from a queue (lazy cleanup).
    fn pop_resident(
        queue: &mut VecDeque<Page>,
        touches: &HashMap<Page, u32>,
        from_back: bool,
    ) -> Option<Page> {
        while let Some(p) = if from_back { queue.pop_back() } else { queue.pop_front() } {
            if touches.contains_key(&p) {
                return Some(p);
            }
        }
        None
    }
}

impl Default for Hpe {
    fn default() -> Self {
        Hpe::new()
    }
}

impl Evictor for Hpe {
    fn name(&self) -> String {
        "HPE".into()
    }

    fn on_access(&mut self, acc: &Access, resident: bool) {
        if resident {
            if let Some(t) = self.touches.get_mut(&acc.page) {
                *t = t.saturating_add(1);
            }
        }
    }

    fn on_migrate(&mut self, page: Page, _via_prefetch: bool) {
        if self.touches.insert(page, 0).is_none() {
            self.new.push_back(page);
        }
        // classifier input: a migration marks this page "touched" in its
        // block — prefetched pages inflate this, by (faithful) design.
        let bb = page / PAGES_PER_BB;
        let c = self.bb_pages.entry(bb).or_insert(0);
        *c = c.saturating_add(1);
    }

    fn on_evict(&mut self, page: Page) {
        // queues are cleaned lazily at pop time
        self.touches.remove(&page);
    }

    fn on_interval(&mut self) {
        self.intervals += 1;
        // age the chain: middle -> old, new -> middle
        let aged: Vec<Page> = self.middle.drain(..).collect();
        self.old.extend(aged.iter().copied());
        let fresh: Vec<Page> = self.new.drain(..).collect();
        self.middle.extend(fresh);
        self.classify();
        // the pages that just became `old` are regular mode's first
        // victims anyway — queue them for background drain instead of
        // waiting for pressure. Classify first: an interval that flips
        // to irregular must NOT schedule its aged warm set for drain.
        if self.proactive && self.mode == Mode::Regular {
            self.drain.extend(aged);
        }
    }

    fn select_victim(&mut self, _mem: &DeviceMemory) -> Option<Page> {
        match self.mode {
            Mode::Regular => {
                // oldest partition, oldest insertion first
                Self::pop_resident(&mut self.old, &self.touches, false)
                    .or_else(|| {
                        Self::pop_resident(&mut self.middle, &self.touches, false)
                    })
                    .or_else(|| {
                        Self::pop_resident(&mut self.new, &self.touches, false)
                    })
            }
            Mode::Irregular => {
                // protect the warm set: sacrifice the newest pages first
                Self::pop_resident(&mut self.new, &self.touches, true)
                    .or_else(|| {
                        Self::pop_resident(&mut self.middle, &self.touches, true)
                    })
                    .or_else(|| {
                        Self::pop_resident(&mut self.old, &self.touches, true)
                    })
            }
        }
    }

    fn pre_evict(&mut self, _view: &MemView<'_>) -> Vec<Page> {
        // drain only while the pattern is regular: in irregular mode
        // the aged partitions are the protected warm set
        if !self.proactive || self.mode != Mode::Regular {
            return Vec::new();
        }
        let mut out = Vec::new();
        while let Some(p) = self.drain.pop_front() {
            match self.touches.get(&p) {
                None => continue, // already evicted: stale entry
                // warm by touch count: drop it from the drain
                // (reactive selection can still take it later)
                Some(&t) if t > DRAIN_TOUCH_GUARD => continue,
                Some(_) => out.push(p),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::belady::count_misses;
    use crate::policy::lru::Lru;

    fn acc(page: Page) -> Access {
        Access { page, pc: 0, tb: 0, kernel: 0, inst_gap: 0, is_write: false }
    }

    #[test]
    fn chain_rotation_moves_partitions() {
        let mut h = Hpe::new();
        h.on_migrate(1, false);
        h.on_interval();
        h.on_migrate(2, false);
        h.on_interval();
        h.on_migrate(3, false);
        // 1 is old, 2 middle, 3 new
        assert_eq!(h.old.front(), Some(&1));
        assert_eq!(h.middle.front(), Some(&2));
        assert_eq!(h.new.front(), Some(&3));
    }

    #[test]
    fn regular_mode_evicts_oldest_partition_first() {
        let mem = DeviceMemory::new(16);
        let mut h = Hpe::new();
        h.on_migrate(1, false);
        h.on_interval();
        h.on_interval(); // 1 -> old
        h.on_migrate(2, false);
        assert_eq!(h.mode, Mode::Regular);
        assert_eq!(h.select_victim(&mem), Some(1));
    }

    #[test]
    fn sparse_blocks_flip_to_irregular_and_protect_old() {
        let mem = DeviceMemory::new(64);
        let mut h = Hpe::new();
        // sparse touches: one page per distinct basic block
        for bb in 0..8u64 {
            h.on_migrate(bb * PAGES_PER_BB, false);
        }
        h.on_interval(); // classify: sparse -> irregular; pages -> middle
        assert_eq!(h.mode, Mode::Irregular);
        h.on_migrate(999 * PAGES_PER_BB, false); // lands in new
        // irregular mode sacrifices the NEW page, protecting the aged set
        assert_eq!(h.select_victim(&mem), Some(999 * PAGES_PER_BB));
    }

    #[test]
    fn dense_blocks_classify_regular() {
        let mut h = Hpe::new();
        for p in 0..PAGES_PER_BB * 2 {
            h.on_migrate(p, false); // two fully dense blocks
        }
        h.on_interval();
        assert_eq!(h.mode, Mode::Regular);
    }

    #[test]
    fn stale_queue_entries_skipped() {
        let mem = DeviceMemory::new(16);
        let mut h = Hpe::new();
        h.on_migrate(1, false);
        h.on_migrate(2, false);
        h.on_evict(1);
        assert_eq!(h.select_victim(&mem), Some(2));
    }

    #[test]
    fn beats_lru_on_thrash_cycle() {
        // cyclic access over capacity+k pages: the LRU pathology.
        // HPE (irregular mode) keeps a warm subset resident and must miss
        // strictly less than LRU's 100% miss rate.
        let seq: Vec<Page> = (0..8u64)
            .map(|p| p * PAGES_PER_BB) // sparse => irregular
            .cycle()
            .take(400)
            .collect();
        let mut h = Hpe::new();
        // prime the classifier with the sparse pattern
        for &p in seq.iter().take(8) {
            h.on_migrate(p, false);
        }
        h.on_interval();
        let hpe = count_misses(&seq, 6, &mut h);
        let lru = count_misses(&seq, 6, &mut Lru::new());
        assert!(hpe < lru, "HPE {hpe} vs LRU {lru}");
    }

    fn view(mem: &DeviceMemory) -> MemView<'_> {
        MemView::new(mem, 0, 0, 0)
    }

    #[test]
    fn proactive_drains_cold_pages_aged_out_of_middle() {
        let mem = DeviceMemory::new(16);
        let mut h = Hpe::proactive();
        h.on_migrate(1, false);
        h.on_interval(); // 1 -> middle
        h.on_interval(); // 1 -> old: queued for drain (regular mode)
        assert_eq!(h.pre_evict(&view(&mem)), vec![1]);
        assert!(h.pre_evict(&view(&mem)).is_empty(), "drain consumed");
    }

    #[test]
    fn warm_drain_candidates_are_skipped() {
        let mem = DeviceMemory::new(16);
        let mut h = Hpe::proactive();
        h.on_migrate(1, false);
        for _ in 0..=DRAIN_TOUCH_GUARD {
            h.on_access(&acc(1), true); // warm: touches > guard
        }
        h.on_interval();
        h.on_interval();
        assert!(h.pre_evict(&view(&mem)).is_empty());
    }

    #[test]
    fn evicted_pages_fall_out_of_the_drain() {
        let mem = DeviceMemory::new(16);
        let mut h = Hpe::proactive();
        h.on_migrate(1, false);
        h.on_migrate(2, false);
        h.on_interval();
        h.on_interval();
        h.on_evict(1); // pressure got there first: stale drain entry
        assert_eq!(h.pre_evict(&view(&mem)), vec![2]);
    }

    #[test]
    fn reactive_and_irregular_modes_never_drain() {
        let mem = DeviceMemory::new(64);
        let mut reactive = Hpe::new();
        reactive.on_migrate(1, false);
        reactive.on_interval();
        reactive.on_interval();
        assert!(reactive.pre_evict(&view(&mem)).is_empty());

        // sparse pattern -> irregular: the aged set is protected
        let mut h = Hpe::proactive();
        for bb in 0..8u64 {
            h.on_migrate(bb * PAGES_PER_BB, false);
        }
        h.on_interval(); // classify: sparse -> irregular
        h.on_interval(); // pages age to old while irregular
        assert_eq!(h.mode, Mode::Irregular);
        assert!(h.pre_evict(&view(&mem)).is_empty());
    }
}
