//! Memory-management policies: the strategy axis of every experiment.
//!
//! The engine-facing surface is the **directive-based decision
//! protocol** in [`decisions`]: a [`DecisionPolicy`] receives typed
//! [`MemEvent`]s (access / fault / interval / kernel boundary plus the
//! decision points those imply) together with a read-only [`MemView`]
//! of residency, occupancy and link state, and answers each with a
//! batched [`Decisions`] value — fault action, prefetch set,
//! **pre-evict set** (routed to the session's background-transfer
//! queue) and pin hints. This is what lets a policy overlap eviction
//! traffic with compute the way the paper's §IV-D engine does; the old
//! reactive [`Policy`] pull trait is kept as a legacy surface and
//! bridged byte-identically through [`LegacyPolicyAdapter`].
//!
//! A policy bundles the decisions the UVM runtime makes — how to
//! *service a fault* (migrate / zero-copy / delayed), what to
//! *prefetch*, whom to *evict* and whom to *pre-evict* — because the
//! paper's central claim is that these must cooperate (Section III-B:
//! HPE collapses when paired with the tree prefetcher it wasn't
//! designed for).
//!
//! Policies are **named and constructed through the open registry** in
//! [`crate::api`]: a [`crate::api::StrategySpec`] pairs a kebab-case
//! name (`"baseline"`, `"demand-belady"`, …) with a factory
//! `Fn(&RunSpec, &StrategyCtx) -> Box<dyn DecisionPolicy>`, so adding a
//! strategy is a single `registry.register(...)` call — no enum edit,
//! no new driver function. The engine itself stays policy-agnostic and
//! only ever sees the trait object.
//!
//! Built-in strategies (all pre-registered by
//! [`crate::api::StrategyRegistry::builtin`]):
//!
//! | module | paper name | role |
//! |---|---|---|
//! | `lru` | Baseline eviction | CUDA driver's LRU (GTC'17) |
//! | `random` | Random | Zheng et al. comparison point |
//! | `tree_prefetch` | Tree. | NVIDIA driver's tree prefetcher (Ganguly) |
//! | `tree_evict` | Tree.+PreEvict | inverse-threshold pre-eviction; the |
//! |              |                | proactive mode emits `pre_evict` |
//! |              |                | directives (registry: `tree-evict`) |
//! | `belady` | D.+Belady. | MIN oracle upper bound |
//! | `hpe` | HPE | hierarchical page eviction (Yu et al.) |
//! | `uvmsmart` | UVMSmart | adaptive DFA-driven runtime (Ganguly) |
//! | `dfa` | — | the 6-class access-pattern classifier both |
//! |       |   | UVMSmart and our framework share |
//! | `composite` | Baseline / Tree.+HPE / D.+X | prefetcher × evictor glue |
//! | `decisions` | — | the decision protocol + legacy adapter |
//!
//! Leaf building blocks keep the narrow [`Evictor`] / [`Prefetcher`]
//! traits and compose into a [`DecisionPolicy`] via
//! [`composite::Composite`]; [`Evictor::pre_evict`] is the hook a
//! proactive evictor uses to surface background pre-eviction
//! candidates through the composite.
//!
//! Registry names (in registration order):
//! `baseline`, `demand-hpe`, `tree-hpe`, `hpe-preevict`, `tree-evict`,
//! `demand-belady`, `demand-lru`, `demand-random`, `uvmsmart`,
//! `intelligent`, `intelligent-native`.
//! The registry-exhaustiveness lint keeps this list in sync with
//! `StrategyRegistry::builtin` and the `BUILTIN` test inventory.

pub mod belady;
pub mod composite;
pub mod decisions;
pub mod dfa;
pub mod hpe;
pub mod lru;
pub mod random;
pub mod tree_evict;
pub mod tree_prefetch;
pub mod uvmsmart;

use crate::sim::{DeviceMemory, FaultAction, Page};
use crate::trace::Access;

pub use decisions::{
    DecisionPolicy, Decisions, LegacyPolicyAdapter, MemEvent, MemView,
};

/// Predictor-side counters a policy may expose after a run. The
/// coordinator uses these for the §V-C overhead injection (one
/// `prediction_overhead` charge per batched inference) and for the
/// instrumentation columns of the paper tables. Rule-based policies keep
/// the all-zero default.
#[derive(Debug, Clone, Copy)]
pub struct PolicyInstrumentation {
    /// batched predictor invocations (overhead is charged per call)
    pub inference_calls: u64,
    /// individual page predictions emitted
    pub predictions: u64,
    /// pattern-specific models instantiated (Table IV `Patterns`)
    pub patterns_used: usize,
    /// final online training loss (NaN when no training ran)
    pub last_loss: f32,
}

impl Default for PolicyInstrumentation {
    fn default() -> Self {
        PolicyInstrumentation {
            inference_calls: 0,
            predictions: 0,
            patterns_used: 0,
            last_loss: f32::NAN,
        }
    }
}

/// The **legacy** pull-style strategy surface: nine imperative hooks the
/// pre-redesign engine called at fixed points. In-tree strategies have
/// migrated to [`DecisionPolicy`]; this trait remains for external /
/// hand-rolled policies, which run unchanged (and byte-identically to
/// the historical engine) through [`LegacyPolicyAdapter`]:
///
/// ```no_run
/// # use uvmio::policy::{LegacyPolicyAdapter, Policy};
/// # use uvmio::sim::{Arena, Session};
/// # use uvmio::config::SimConfig;
/// # fn wrap(cfg: SimConfig, arena: Arena, old: Box<dyn Policy>) {
/// let session =
///     Session::new(cfg, arena, Box::new(LegacyPolicyAdapter::new(old)));
/// # let _ = session;
/// # }
/// ```
pub trait Policy {
    fn name(&self) -> String;

    /// Predictor instrumentation for overhead accounting (default: none).
    fn instrumentation(&self) -> PolicyInstrumentation {
        PolicyInstrumentation::default()
    }

    /// Observe an access (after residency is known, before servicing).
    fn on_access(&mut self, _acc: &Access, _resident: bool) {}

    /// How to service a far-fault on `page` (default: migrate).
    fn fault_action(&mut self, _page: Page) -> FaultAction {
        FaultAction::Migrate
    }

    /// Pages to prefetch after servicing `acc` (non-resident pages only;
    /// the engine filters and bounds them by the arena).
    fn prefetch(&mut self, _acc: &Access) -> Vec<Page> {
        Vec::new()
    }

    /// Choose an eviction victim. Must return a resident page; the engine
    /// falls back (and counts `policy_victim_fallbacks`) otherwise.
    fn select_victim(&mut self, mem: &DeviceMemory) -> Option<Page>;

    /// A page became resident (demand or prefetch).
    fn on_migrate(&mut self, _page: Page, _via_prefetch: bool) {}

    /// A page was evicted.
    fn on_evict(&mut self, _page: Page) {}

    /// Interval boundary (every `SimConfig::interval_faults` faults) —
    /// HPE rotates its page-set chain here, frequency tables flush, etc.
    fn on_interval(&mut self) {}

    /// Kernel (phase) boundary.
    fn on_kernel_boundary(&mut self, _kernel: u32) {}
}

/// Forwarding impl so a borrowed legacy policy can be adapted without
/// giving up ownership.
impl<P: Policy + ?Sized> Policy for &mut P {
    fn name(&self) -> String {
        (**self).name()
    }

    fn instrumentation(&self) -> PolicyInstrumentation {
        (**self).instrumentation()
    }

    fn on_access(&mut self, acc: &Access, resident: bool) {
        (**self).on_access(acc, resident)
    }

    fn fault_action(&mut self, page: Page) -> FaultAction {
        (**self).fault_action(page)
    }

    fn prefetch(&mut self, acc: &Access) -> Vec<Page> {
        (**self).prefetch(acc)
    }

    fn select_victim(&mut self, mem: &DeviceMemory) -> Option<Page> {
        (**self).select_victim(mem)
    }

    fn on_migrate(&mut self, page: Page, via_prefetch: bool) {
        (**self).on_migrate(page, via_prefetch)
    }

    fn on_evict(&mut self, page: Page) {
        (**self).on_evict(page)
    }

    fn on_interval(&mut self) {
        (**self).on_interval()
    }

    fn on_kernel_boundary(&mut self, kernel: u32) {
        (**self).on_kernel_boundary(kernel)
    }
}

/// Boxed legacy policies are policies too — this is what lets
/// [`LegacyPolicyAdapter`] wrap a `Box<dyn Policy>` directly.
impl<P: Policy + ?Sized> Policy for Box<P> {
    fn name(&self) -> String {
        (**self).name()
    }

    fn instrumentation(&self) -> PolicyInstrumentation {
        (**self).instrumentation()
    }

    fn on_access(&mut self, acc: &Access, resident: bool) {
        (**self).on_access(acc, resident)
    }

    fn fault_action(&mut self, page: Page) -> FaultAction {
        (**self).fault_action(page)
    }

    fn prefetch(&mut self, acc: &Access) -> Vec<Page> {
        (**self).prefetch(acc)
    }

    fn select_victim(&mut self, mem: &DeviceMemory) -> Option<Page> {
        (**self).select_victim(mem)
    }

    fn on_migrate(&mut self, page: Page, via_prefetch: bool) {
        (**self).on_migrate(page, via_prefetch)
    }

    fn on_evict(&mut self, page: Page) {
        (**self).on_evict(page)
    }

    fn on_interval(&mut self) {
        (**self).on_interval()
    }

    fn on_kernel_boundary(&mut self, kernel: u32) {
        (**self).on_kernel_boundary(kernel)
    }
}

/// Eviction-only strategies that compose with any prefetcher via
/// [`composite::Composite`].
pub trait Evictor {
    fn name(&self) -> String;
    fn on_access(&mut self, _acc: &Access, _resident: bool) {}
    fn select_victim(&mut self, mem: &DeviceMemory) -> Option<Page>;

    /// Background pre-eviction candidates, drained by the composite at
    /// each fault-serviced decision point and routed to the session's
    /// background-transfer queue. Reactive evictors keep the empty
    /// default; a proactive evictor (e.g.
    /// [`tree_evict::TreeEvict::proactive`]) returns the victims it
    /// wants moved out *before* memory pressure forces the issue.
    fn pre_evict(&mut self, _view: &MemView<'_>) -> Vec<Page> {
        Vec::new()
    }

    fn on_migrate(&mut self, _page: Page, _via_prefetch: bool) {}
    fn on_evict(&mut self, _page: Page) {}
    fn on_interval(&mut self) {}
    fn on_kernel_boundary(&mut self, _kernel: u32) {}
}

/// Prefetch-only strategies for the same composition.
pub trait Prefetcher {
    fn name(&self) -> String;
    fn on_access(&mut self, _acc: &Access, _resident: bool) {}
    fn prefetch(&mut self, _acc: &Access) -> Vec<Page> {
        Vec::new()
    }
    fn on_migrate(&mut self, _page: Page, _via_prefetch: bool) {}
    fn on_evict(&mut self, _page: Page) {}
}

/// No prefetching — the paper's "Demand." configurations.
#[derive(Debug, Default)]
pub struct DemandOnly;

impl Prefetcher for DemandOnly {
    fn name(&self) -> String {
        "Demand".into()
    }
}
