//! The directive-based decision protocol between the engine and a
//! policy: typed [`MemEvent`]s in, batched [`Decisions`] out.
//!
//! The original `Policy` trait was nine imperative pull hooks
//! (`on_access`, `fault_action`, `prefetch`, `select_victim`, …) that
//! the engine called at fixed points, each answering one narrow
//! question. That shape cannot express the paper's headline mechanism:
//! *pre-eviction* (§IV-D) — moving victims out over the link **ahead**
//! of memory pressure so demand migrations never stall behind
//! evictions. A pull hook only runs when the engine already needs a
//! frame; by then the eviction is on the critical path.
//!
//! [`DecisionPolicy`] inverts that: the engine narrates the simulation
//! as [`MemEvent`]s (mirroring the [`crate::sim::SimEvent`] vocabulary
//! — access, fault, interval, kernel boundary, plus the decision points
//! those imply) and the policy answers each with a [`Decisions`] value:
//! the fault-service action, a prefetch set, a **pre-evict set** routed
//! to the session's background-transfer queue, and optional pin hints.
//! A read-only [`MemView`] accompanies every event, so policies reason
//! about residency, occupancy and link state without groping
//! `DeviceMemory` (or worse, mirroring engine state they cannot see).
//!
//! Which [`Decisions`] fields the engine honours depends on the event —
//! the protocol is deliberately narrow to keep re-entrancy impossible:
//!
//! | event | honoured fields |
//! |---|---|
//! | [`MemEvent::Fault`] | `fault_action` |
//! | [`MemEvent::FaultServiced`] | `prefetch`, `pre_evict` |
//! | [`MemEvent::Interval`] | `pre_evict` |
//! | [`MemEvent::VictimNeeded`] | `victim` |
//! | every event | `pin` / `unpin` |
//!
//! Old-style [`Policy`] implementations keep working through
//! [`LegacyPolicyAdapter`], which replays the exact pull-hook call
//! order the pre-redesign engine used — a legacy policy driven through
//! the adapter is byte-identical to its historical behaviour (pinned by
//! the adapter-equivalence suite in `tests/decisions.rs`).

use crate::sim::mem::Frame;
use crate::sim::{DeviceMemory, FaultAction, Page};
use crate::trace::Access;

use super::{Policy, PolicyInstrumentation};

/// One engine-side event a policy is asked to decide on. Mirrors the
/// [`crate::sim::SimEvent`] vocabulary from the policy's perspective:
/// notifications (`Access`, `Migrated`, `Evicted`, `Interval`,
/// `KernelBoundary`) interleaved with the three decision points
/// (`Fault`, `FaultServiced`, `VictimNeeded`).
#[derive(Debug, Clone, Copy)]
pub enum MemEvent<'a> {
    /// An access is about to be serviced; `resident` is the residency
    /// determination the engine just made.
    Access { acc: &'a Access, resident: bool },
    /// A far-fault needs a service action (`Decisions::fault_action`;
    /// `None` defaults to [`FaultAction::Migrate`]).
    Fault { acc: &'a Access },
    /// The fault was serviced with `action` (the *effective* action —
    /// a `Delay` that crossed the soft-pin threshold surfaces as
    /// `Migrate`). This is the batched decision point: the driver
    /// schedules prefetch and pre-eviction DMA while the fault batch is
    /// in flight, so `prefetch` and `pre_evict` are honoured here.
    FaultServiced { acc: &'a Access, action: FaultAction },
    /// A demand admission needs a frame NOW; `Decisions::victim` names
    /// the page to evict (must be resident and ≠ `incoming`, else the
    /// engine falls back and counts `policy_victim_fallbacks`).
    VictimNeeded { incoming: Page },
    /// A page became resident (demand migration or prefetch).
    Migrated { page: Page, via_prefetch: bool },
    /// A page was evicted; `pre_evicted` distinguishes a background
    /// pre-eviction from a demand-path eviction.
    Evicted { page: Page, pre_evicted: bool },
    /// An eviction interval elapsed (`SimConfig::interval_faults`).
    Interval { index: u64 },
    /// The input stream crossed a kernel (phase) boundary.
    KernelBoundary { kernel: u32 },
}

/// Read-only residency / occupancy / clock context handed to every
/// [`DecisionPolicy::decide`] call. This is the sanctioned way for a
/// policy to see engine state: occupancy for pressure heuristics,
/// per-frame metadata (touch counts, dirty bits) for warmth guards,
/// link state for background-traffic pacing.
#[derive(Clone, Copy)]
pub struct MemView<'a> {
    mem: &'a DeviceMemory,
    now: u64,
    link_free_at: u64,
    link_busy_cycles: u64,
}

impl<'a> MemView<'a> {
    pub fn new(
        mem: &'a DeviceMemory,
        now: u64,
        link_free_at: u64,
        link_busy_cycles: u64,
    ) -> MemView<'a> {
        MemView { mem, now, link_free_at, link_busy_cycles }
    }

    /// The device memory itself (resident set + frame metadata).
    pub fn memory(&self) -> &'a DeviceMemory {
        self.mem
    }

    pub fn resident(&self, page: Page) -> bool {
        self.mem.resident(page)
    }

    /// Frame metadata of a resident page (touch count, dirty bit,
    /// install cycle, prefetched-untouched flag). By value — the dense
    /// page table synthesizes the `Frame` from its column arrays.
    pub fn frame(&self, page: Page) -> Option<Frame> {
        self.mem.frame(page)
    }

    pub fn used(&self) -> u64 {
        self.mem.used()
    }

    pub fn capacity(&self) -> u64 {
        self.mem.capacity()
    }

    /// Frames currently free (`capacity - used`).
    pub fn free_frames(&self) -> u64 {
        self.mem.capacity() - self.mem.used()
    }

    pub fn is_full(&self) -> bool {
        self.mem.is_full()
    }

    /// Current simulated cycle.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// First cycle at which the shared interconnect is idle again.
    pub fn link_free_at(&self) -> u64 {
        self.link_free_at
    }

    /// True when the interconnect is idle right now — the slack window
    /// the background-transfer queue schedules dirty writebacks into.
    pub fn link_idle(&self) -> bool {
        self.link_free_at <= self.now
    }

    /// Total interconnect occupancy reserved so far.
    pub fn link_busy_cycles(&self) -> u64 {
        self.link_busy_cycles
    }

    /// Of `pages` (a prospective `pre_evict` set), how many the
    /// background-transfer queue could actually free **right now**
    /// under its slack rule: clean resident pages drop immediately,
    /// while at most one dirty page writes back — and only if the link
    /// is idle. Policies bounding prefetch bursts by available frames
    /// should use this, not `pages.len()`, so held-back dirty
    /// candidates are not double-counted as free frames.
    pub fn pre_evictable_now(&self, pages: &[Page]) -> usize {
        let mut dirty_budget = usize::from(self.link_idle());
        let mut n = 0;
        for &p in pages {
            match self.frame(p) {
                Some(f) if !f.dirty => n += 1,
                Some(_) if dirty_budget > 0 => {
                    dirty_budget -= 1;
                    n += 1;
                }
                _ => {}
            }
        }
        n
    }
}

/// The batched answer to one [`MemEvent`]. Fields the current event
/// does not honour (see the module-level table) are ignored. The
/// default value decides nothing — return it from notification events.
#[derive(Debug, Clone, Default)]
pub struct Decisions {
    /// How to service the fault (honoured on [`MemEvent::Fault`];
    /// `None` defaults to [`FaultAction::Migrate`]).
    pub fault_action: Option<FaultAction>,
    /// Eviction victim (honoured on [`MemEvent::VictimNeeded`]).
    pub victim: Option<Page>,
    /// Pages to prefetch; the engine filters non-allocated and resident
    /// candidates and admits the rest as background link transfers
    /// (honoured on [`MemEvent::FaultServiced`]).
    pub prefetch: Vec<Page>,
    /// Resident pages to pre-evict through the session's
    /// background-transfer queue (honoured on
    /// [`MemEvent::FaultServiced`] and [`MemEvent::Interval`]); dirty
    /// pages write back over the link only when it has slack, so
    /// background eviction traffic yields to demand migrations.
    pub pre_evict: Vec<Page>,
    /// Pin hints: pinned pages are exempt from background pre-eviction
    /// (demand-path victim choices are the policy's own and are not
    /// filtered). Honoured on every event.
    pub pin: Vec<Page>,
    /// Release previously pinned pages. Honoured on every event.
    pub unpin: Vec<Page>,
}

impl Decisions {
    /// Decide nothing (the right answer to pure notifications).
    pub fn none() -> Decisions {
        Decisions::default()
    }

    /// A fault-service decision.
    pub fn fault(action: FaultAction) -> Decisions {
        Decisions { fault_action: Some(action), ..Decisions::default() }
    }

    /// A victim nomination (None lets the engine fall back).
    pub fn victim(page: Option<Page>) -> Decisions {
        Decisions { victim: page, ..Decisions::default() }
    }

    pub fn with_prefetch(mut self, pages: Vec<Page>) -> Decisions {
        self.prefetch = pages;
        self
    }

    pub fn with_pre_evict(mut self, pages: Vec<Page>) -> Decisions {
        self.pre_evict = pages;
        self
    }

    pub fn with_pin(mut self, pages: Vec<Page>) -> Decisions {
        self.pin = pages;
        self
    }

    pub fn with_unpin(mut self, pages: Vec<Page>) -> Decisions {
        self.unpin = pages;
        self
    }

    /// Reset to "decide nothing" while keeping the vector capacities.
    /// The session clears its scratch this way before every `decide`
    /// call, so the steady-state hot path allocates nothing for empty
    /// decision sets.
    pub fn clear(&mut self) {
        self.fault_action = None;
        self.victim = None;
        self.prefetch.clear();
        self.pre_evict.clear();
        self.pin.clear();
        self.unpin.clear();
    }
}

/// A complete memory-management strategy under the directive protocol:
/// the engine narrates [`MemEvent`]s, the policy answers each with a
/// [`Decisions`] value. See the module docs for which fields each event
/// honours. Implementations must be deterministic — the sweep runner's
/// serial ≡ parallel byte-identity contract extends through the
/// background-transfer queue.
pub trait DecisionPolicy {
    fn name(&self) -> String;

    /// Predictor instrumentation for overhead accounting (default: none).
    fn instrumentation(&self) -> PolicyInstrumentation {
        PolicyInstrumentation::default()
    }

    /// The single decision entry point. `out` is a **caller-owned
    /// scratch** that arrives cleared — the caller guarantees
    /// [`Decisions::clear`] ran; policies must not assume the callee
    /// clears it — so implementations write directives into it instead
    /// of allocating a fresh value per event. Wrappers that delegate
    /// pass `out` through untouched; a policy composing several inner
    /// `decide` calls manages clearing between them itself.
    fn decide(
        &mut self,
        event: &MemEvent<'_>,
        view: &MemView<'_>,
        out: &mut Decisions,
    );
}

/// Forwarding impl so a borrowed policy drives an owning session —
/// [`crate::sim::Engine::run`] borrows its policy and wraps the borrow.
impl<P: DecisionPolicy + ?Sized> DecisionPolicy for &mut P {
    fn name(&self) -> String {
        (**self).name()
    }

    fn instrumentation(&self) -> PolicyInstrumentation {
        (**self).instrumentation()
    }

    fn decide(
        &mut self,
        event: &MemEvent<'_>,
        view: &MemView<'_>,
        out: &mut Decisions,
    ) {
        (**self).decide(event, view, out)
    }
}

impl<P: DecisionPolicy + ?Sized> DecisionPolicy for Box<P> {
    fn name(&self) -> String {
        (**self).name()
    }

    fn instrumentation(&self) -> PolicyInstrumentation {
        (**self).instrumentation()
    }

    fn decide(
        &mut self,
        event: &MemEvent<'_>,
        view: &MemView<'_>,
        out: &mut Decisions,
    ) {
        (**self).decide(event, view, out)
    }
}

/// Adapts any old-style pull [`Policy`] to the decision protocol by
/// replaying the pre-redesign engine's exact hook order: `on_access` at
/// [`MemEvent::Access`], `fault_action` at [`MemEvent::Fault`],
/// `prefetch` at [`MemEvent::FaultServiced`] (i.e. *after* the demand
/// migration, exactly when the old engine queried it), `select_victim`
/// at [`MemEvent::VictimNeeded`], and the notification hooks at their
/// events. An adapted policy therefore produces byte-identical
/// simulations to the historical engine; it never emits `pre_evict`
/// directives (the old trait cannot express them).
pub struct LegacyPolicyAdapter<P: Policy + ?Sized> {
    inner: P,
}

impl<P: Policy> LegacyPolicyAdapter<P> {
    pub fn new(inner: P) -> LegacyPolicyAdapter<P> {
        LegacyPolicyAdapter { inner }
    }
}

impl<P: Policy + ?Sized> LegacyPolicyAdapter<P> {
    /// The wrapped legacy policy.
    pub fn inner(&self) -> &P {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut P {
        &mut self.inner
    }
}

impl<P: Policy + ?Sized> DecisionPolicy for LegacyPolicyAdapter<P> {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn instrumentation(&self) -> PolicyInstrumentation {
        self.inner.instrumentation()
    }

    fn decide(
        &mut self,
        event: &MemEvent<'_>,
        view: &MemView<'_>,
        out: &mut Decisions,
    ) {
        match *event {
            MemEvent::Access { acc, resident } => {
                self.inner.on_access(acc, resident);
            }
            MemEvent::Fault { acc } => {
                out.fault_action = Some(self.inner.fault_action(acc.page));
            }
            MemEvent::FaultServiced { acc, .. } => {
                out.prefetch.extend(self.inner.prefetch(acc));
            }
            MemEvent::VictimNeeded { .. } => {
                out.victim = self.inner.select_victim(view.memory());
            }
            MemEvent::Migrated { page, via_prefetch } => {
                self.inner.on_migrate(page, via_prefetch);
            }
            MemEvent::Evicted { page, .. } => {
                self.inner.on_evict(page);
            }
            MemEvent::Interval { .. } => {
                self.inner.on_interval();
            }
            MemEvent::KernelBoundary { kernel } => {
                self.inner.on_kernel_boundary(kernel);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::FaultAction;

    fn acc(page: Page) -> Access {
        Access { page, pc: 0, tb: 0, kernel: 0, inst_gap: 0, is_write: false }
    }

    /// Drive one decide call through a fresh scratch (what the session
    /// does with its reusable one).
    fn decide<P: DecisionPolicy>(
        p: &mut P,
        event: MemEvent<'_>,
        view: &MemView<'_>,
    ) -> Decisions {
        let mut d = Decisions::none();
        p.decide(&event, view, &mut d);
        d
    }

    /// A legacy policy recording its hook-call order.
    #[derive(Default)]
    struct Spy {
        calls: Vec<&'static str>,
    }

    impl Policy for Spy {
        fn name(&self) -> String {
            "spy".into()
        }

        fn on_access(&mut self, _acc: &Access, _resident: bool) {
            self.calls.push("on_access");
        }

        fn fault_action(&mut self, _page: Page) -> FaultAction {
            self.calls.push("fault_action");
            FaultAction::ZeroCopy
        }

        fn prefetch(&mut self, acc: &Access) -> Vec<Page> {
            self.calls.push("prefetch");
            vec![acc.page + 1]
        }

        fn select_victim(&mut self, _mem: &DeviceMemory) -> Option<Page> {
            self.calls.push("select_victim");
            Some(9)
        }

        fn on_migrate(&mut self, _page: Page, _via_prefetch: bool) {
            self.calls.push("on_migrate");
        }

        fn on_evict(&mut self, _page: Page) {
            self.calls.push("on_evict");
        }

        fn on_interval(&mut self) {
            self.calls.push("on_interval");
        }

        fn on_kernel_boundary(&mut self, _kernel: u32) {
            self.calls.push("on_kernel_boundary");
        }
    }

    #[test]
    fn adapter_routes_every_event_to_its_hook() {
        let mem = DeviceMemory::new(4);
        let view = MemView::new(&mem, 0, 0, 0);
        let a = acc(5);
        let mut ad = LegacyPolicyAdapter::new(Spy::default());

        let d = decide(&mut ad, MemEvent::Access { acc: &a, resident: false }, &view);
        assert!(d.fault_action.is_none() && d.prefetch.is_empty());
        let d = decide(&mut ad, MemEvent::Fault { acc: &a }, &view);
        assert_eq!(d.fault_action, Some(FaultAction::ZeroCopy));
        let d = decide(
            &mut ad,
            MemEvent::FaultServiced { acc: &a, action: FaultAction::Migrate },
            &view,
        );
        assert_eq!(d.prefetch, vec![6]);
        assert!(d.pre_evict.is_empty(), "legacy policies cannot pre-evict");
        let d = decide(&mut ad, MemEvent::VictimNeeded { incoming: 5 }, &view);
        assert_eq!(d.victim, Some(9));
        decide(&mut ad, MemEvent::Migrated { page: 5, via_prefetch: false }, &view);
        decide(&mut ad, MemEvent::Evicted { page: 9, pre_evicted: false }, &view);
        decide(&mut ad, MemEvent::Interval { index: 1 }, &view);
        decide(&mut ad, MemEvent::KernelBoundary { kernel: 2 }, &view);
        assert_eq!(
            ad.inner().calls,
            vec![
                "on_access",
                "fault_action",
                "prefetch",
                "select_victim",
                "on_migrate",
                "on_evict",
                "on_interval",
                "on_kernel_boundary",
            ]
        );
    }

    #[test]
    fn view_exposes_residency_and_link_state() {
        let mut mem = DeviceMemory::new(3);
        mem.install(7, 10, false);
        mem.touch(7, true);
        let view = MemView::new(&mem, 100, 150, 40);
        assert!(view.resident(7));
        assert!(!view.resident(8));
        assert_eq!(view.used(), 1);
        assert_eq!(view.capacity(), 3);
        assert_eq!(view.free_frames(), 2);
        assert!(!view.is_full());
        assert_eq!(view.now(), 100);
        assert!(!view.link_idle(), "busy until 150");
        assert_eq!(view.link_busy_cycles(), 40);
        let f = view.frame(7).unwrap();
        assert!(f.dirty);
        assert_eq!(f.touches, 1);
    }

    #[test]
    fn pre_evictable_now_honours_the_slack_rule() {
        let mut mem = DeviceMemory::new(8);
        for p in [1u64, 2, 3] {
            mem.install(p, 0, false);
        }
        mem.touch(2, true); // dirty
        mem.touch(3, true); // dirty
        let pages = [1u64, 2, 3, 99]; // 99: not resident
        // idle link: clean page 1 + ONE dirty page can free now
        let idle = MemView::new(&mem, 100, 50, 0);
        assert_eq!(idle.pre_evictable_now(&pages), 2);
        // busy link: only the clean page frees
        let busy = MemView::new(&mem, 100, 500, 0);
        assert_eq!(busy.pre_evictable_now(&pages), 1);
    }

    #[test]
    fn decisions_builders_compose() {
        let d = Decisions::fault(FaultAction::Delay)
            .with_prefetch(vec![1, 2])
            .with_pre_evict(vec![3])
            .with_pin(vec![4])
            .with_unpin(vec![5]);
        assert_eq!(d.fault_action, Some(FaultAction::Delay));
        assert_eq!(d.prefetch, vec![1, 2]);
        assert_eq!(d.pre_evict, vec![3]);
        assert_eq!(d.pin, vec![4]);
        assert_eq!(d.unpin, vec![5]);
        assert!(Decisions::none().victim.is_none());
    }

    #[test]
    fn clear_resets_everything_but_keeps_capacity() {
        let mut d = Decisions::fault(FaultAction::Delay)
            .with_prefetch(vec![1, 2, 3])
            .with_pre_evict(vec![4])
            .with_pin(vec![5])
            .with_unpin(vec![6]);
        d.victim = Some(7);
        let cap = d.prefetch.capacity();
        d.clear();
        assert!(d.fault_action.is_none() && d.victim.is_none());
        assert!(d.prefetch.is_empty() && d.pre_evict.is_empty());
        assert!(d.pin.is_empty() && d.unpin.is_empty());
        assert!(
            d.prefetch.capacity() >= cap,
            "clear must retain buffer capacity for reuse"
        );
    }
}
