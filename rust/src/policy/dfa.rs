//! The DFA access-pattern classifier (Ganguly et al., DATE'21 — reused by
//! the paper as its pattern classifier, §IV-C).
//!
//! The UVM runtime batches far-faults into 64 KB basic-block DMA
//! transfers; the DFA scans the transfer stream segregated at kernel
//! boundaries and labels each segment with one of six patterns by
//! (a) linearity/randomness of the block addresses and (b) re-referencing
//! across kernel boundaries:
//!
//! `Streaming`, `Random`, `Mixed` × (reuse? `LinearReuse`/`RandomReuse`/
//! `MixedReuse`).
//!
//! Not a policy itself: [`DfaClassifier`] is the shared *detection
//! engine* that UVMSmart and the intelligent framework embed. Under the
//! decision API its owners feed it from `Migrated` events (demand
//! traffic only) and close segments at `KernelBoundary` events.

use std::collections::HashSet;

use crate::config::PAGES_PER_BB;
use crate::sim::Page;

/// The six DFA classes (paper §IV-C digits 0-5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Pattern {
    Streaming,
    Random,
    Mixed,
    LinearReuse,
    RandomReuse,
    MixedReuse,
}

impl Pattern {
    pub const COUNT: usize = 6;

    pub fn index(&self) -> usize {
        match self {
            Pattern::Streaming => 0,
            Pattern::Random => 1,
            Pattern::Mixed => 2,
            Pattern::LinearReuse => 3,
            Pattern::RandomReuse => 4,
            Pattern::MixedReuse => 5,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Pattern::Streaming => "Streaming",
            Pattern::Random => "Random",
            Pattern::Mixed => "Mixed",
            Pattern::LinearReuse => "LinearReuse",
            Pattern::RandomReuse => "RandomReuse",
            Pattern::MixedReuse => "MixedReuse",
        }
    }

    pub fn is_linear(&self) -> bool {
        matches!(self, Pattern::Streaming | Pattern::LinearReuse)
    }

    pub fn is_random(&self) -> bool {
        matches!(self, Pattern::Random | Pattern::RandomReuse)
    }

    pub fn has_reuse(&self) -> bool {
        matches!(
            self,
            Pattern::LinearReuse | Pattern::RandomReuse | Pattern::MixedReuse
        )
    }
}

/// Classify a basic-block address sequence given the set of blocks seen in
/// earlier segments. Pure function — the invariant tests lean on this.
///
/// Linearity detection is *multi-stream aware*: real UVM transfer streams
/// interleave several linear walks (one per `cudaMallocManaged` array), so
/// instead of demanding +1 deltas we measure how much of the transition
/// mass is covered by the few most common deltas. A periodic delta cycle
/// (streaming over k arrays) concentrates in ≤ k+1 distinct deltas; a
/// random walk spreads across many.
pub fn classify_blocks(blocks: &[u64], seen_before: &HashSet<u64>) -> Pattern {
    if blocks.len() < 2 {
        return Pattern::Streaming; // too little signal: optimistic default
    }
    let mut hist: std::collections::HashMap<i64, usize> =
        std::collections::HashMap::new();
    for w in blocks.windows(2) {
        *hist.entry(w[1] as i64 - w[0] as i64).or_insert(0) += 1;
    }
    let n = (blocks.len() - 1) as f64;
    let mut counts: Vec<usize> = hist.values().copied().collect();
    counts.sort_unstable_by(|a, b| b.cmp(a));
    let top4: usize = counts.iter().take(4).sum();
    let top4_frac = top4 as f64 / n;
    let reuse = blocks.iter().filter(|b| seen_before.contains(b)).count();
    let reuse_frac = reuse as f64 / blocks.len() as f64;

    let base = if top4_frac >= 0.70 {
        0 // linear / periodic multi-stream
    } else if hist.len() >= 16 && top4_frac < 0.40 {
        1 // random: many distinct jumps, no dominant period
    } else {
        2 // mixed
    };
    match (base, reuse_frac >= 0.3) {
        (0, false) => Pattern::Streaming,
        (1, false) => Pattern::Random,
        (2, false) => Pattern::Mixed,
        (0, true) => Pattern::LinearReuse,
        (1, true) => Pattern::RandomReuse,
        (2, true) => Pattern::MixedReuse,
        _ => unreachable!(),
    }
}

/// Stateful classifier fed by the migration (DMA) stream.
#[derive(Debug, Default)]
pub struct DfaClassifier {
    seen: HashSet<u64>,
    segment: Vec<u64>,
    last: Option<Pattern>,
    /// bounded history so long runs don't grow without limit
    max_segment: usize,
}

impl DfaClassifier {
    pub fn new() -> DfaClassifier {
        DfaClassifier {
            seen: HashSet::new(),
            segment: Vec::new(),
            last: None,
            max_segment: 4096,
        }
    }

    /// Record a page migration (the DFA sees its basic block).
    pub fn note_transfer(&mut self, page: Page) {
        if self.segment.len() < self.max_segment {
            self.segment.push(page / PAGES_PER_BB);
        }
    }

    /// Kernel boundary: classify the finished segment and reset.
    pub fn kernel_boundary(&mut self) -> Pattern {
        let p = classify_blocks(&self.segment, &self.seen);
        self.seen.extend(self.segment.drain(..));
        self.last = Some(p);
        p
    }

    /// Classify the in-flight segment without closing it (used by the
    /// online framework between boundaries).
    pub fn classify_current(&self) -> Pattern {
        self.last
            .unwrap_or_else(|| classify_blocks(&self.segment, &self.seen))
    }

    /// Most recent closed-segment classification.
    pub fn last(&self) -> Option<Pattern> {
        self.last
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bbs(v: &[u64]) -> Vec<u64> {
        v.to_vec()
    }

    /// scattered walk with all-distinct deltas
    fn scatter(n: u64) -> Vec<u64> {
        (0..n).map(|i| (i * i * 2654435761 >> 5) % 997).collect()
    }

    #[test]
    fn linear_no_reuse_is_streaming() {
        let p = classify_blocks(&bbs(&[0, 1, 2, 3, 4, 5]), &HashSet::new());
        assert_eq!(p, Pattern::Streaming);
    }

    #[test]
    fn large_jumps_are_random() {
        // a long scattered walk: every delta distinct
        let p = classify_blocks(&scatter(32), &HashSet::new());
        assert_eq!(p, Pattern::Random);
    }

    #[test]
    fn interleaved_streams_are_still_linear() {
        // three arrays streamed together: the delta cycle {+42, +43, -84}
        // repeats — multi-stream streaming, not random
        let mut blocks = Vec::new();
        for i in 0..40u64 {
            blocks.push(i);
            blocks.push(42 + i);
            blocks.push(85 + i);
        }
        let p = classify_blocks(&blocks, &HashSet::new());
        assert_eq!(p, Pattern::Streaming);
    }

    #[test]
    fn alternating_is_mixed() {
        // half a dominant +1 walk, half scattered jumps: neither linear-
        // nor random-dominant
        let mut blocks = Vec::new();
        for i in 0..30u64 {
            blocks.push(i);
            blocks.push(i + 1);
            blocks.push(i + 2);
            blocks.push((i * i * 31337 >> 3) % 900);
        }
        let p = classify_blocks(&blocks, &HashSet::new());
        assert_eq!(p, Pattern::Mixed);
    }

    #[test]
    fn reuse_upgrades_class() {
        let seen: HashSet<u64> = (0..1000).collect();
        let p = classify_blocks(&bbs(&[0, 1, 2, 3, 4, 5]), &seen);
        assert_eq!(p, Pattern::LinearReuse);
        let p = classify_blocks(&scatter(32), &seen);
        assert_eq!(p, Pattern::RandomReuse);
    }

    #[test]
    fn stateful_cross_kernel_reuse() {
        let mut d = DfaClassifier::new();
        for p in 0..64 {
            d.note_transfer(p); // bbs 0..4 linear
        }
        assert_eq!(d.kernel_boundary(), Pattern::Streaming);
        // second kernel re-touches the same blocks
        for p in 0..64 {
            d.note_transfer(p);
        }
        assert_eq!(d.kernel_boundary(), Pattern::LinearReuse);
    }

    #[test]
    fn classification_is_pure() {
        let seen: HashSet<u64> = HashSet::new();
        let blocks = bbs(&[5, 6, 7, 8, 2, 9]);
        assert_eq!(
            classify_blocks(&blocks, &seen),
            classify_blocks(&blocks, &seen)
        );
    }

    #[test]
    fn workload_categories_match_table7() {
        use crate::config::Scale;
        use crate::trace::workloads::Workload;
        // feed each benchmark's page stream through the DFA and check the
        // headline category of the paper's Table VII rows
        let classify = |w: Workload| {
            let t = w.generate(Scale::default(), 42);
            let mut d = DfaClassifier::new();
            let mut votes = [0usize; Pattern::COUNT];
            let mut kernel = 0;
            for a in &t.accesses {
                if a.kernel != kernel {
                    kernel = a.kernel;
                    votes[d.kernel_boundary().index()] += 1;
                }
                d.note_transfer(a.page);
            }
            votes[d.kernel_boundary().index()] += 1;
            votes
        };
        let triad = classify(Workload::StreamTriad);
        assert!(
            triad[Pattern::Streaming.index()] + triad[Pattern::LinearReuse.index()]
                >= triad.iter().sum::<usize>() / 2,
            "StreamTriad should be linear: {triad:?}"
        );
        let atax = classify(Workload::Atax);
        assert!(
            atax[Pattern::Random.index()] + atax[Pattern::RandomReuse.index()]
                + atax[Pattern::Mixed.index()] + atax[Pattern::MixedReuse.index()] > 0,
            "ATAX transpose phase should look non-linear: {atax:?}"
        );
    }
}
