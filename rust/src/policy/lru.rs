//! LRU eviction — the CUDA driver's page replacement policy (GTC'17),
//! and the evictor half of the paper's Baseline (tree prefetch + LRU).
//!
//! True LRU over pages: O(log n) via a tick-indexed BTreeMap. The paper
//! notes ideal LRU is too expensive in hardware; the simulator models the
//! idealised policy, as GPGPU-Sim does.
//!
//! A purely reactive [`Evictor`]: it answers `select_victim` pulls from
//! the composite's `VictimNeeded` decision and never emits `pre_evict`
//! directives (the [`crate::policy::Evictor::pre_evict`] default).

use std::collections::{BTreeMap, HashMap};

use crate::sim::{DeviceMemory, Page};
use crate::trace::Access;

use super::Evictor;

#[derive(Debug, Default)]
pub struct Lru {
    tick: u64,
    by_tick: BTreeMap<u64, Page>,
    tick_of: HashMap<Page, u64>,
}

impl Lru {
    pub fn new() -> Lru {
        Lru::default()
    }

    fn bump(&mut self, page: Page) {
        self.tick += 1;
        if let Some(old) = self.tick_of.insert(page, self.tick) {
            self.by_tick.remove(&old);
        }
        self.by_tick.insert(self.tick, page);
    }

    fn drop_page(&mut self, page: Page) {
        if let Some(t) = self.tick_of.remove(&page) {
            self.by_tick.remove(&t);
        }
    }

    /// Number of tracked pages (resident set size).
    pub fn len(&self) -> usize {
        self.tick_of.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tick_of.is_empty()
    }
}

impl Evictor for Lru {
    fn name(&self) -> String {
        "LRU".into()
    }

    fn on_access(&mut self, acc: &Access, resident: bool) {
        if resident {
            self.bump(acc.page);
        }
    }

    fn on_migrate(&mut self, page: Page, _via_prefetch: bool) {
        self.bump(page);
    }

    fn on_evict(&mut self, page: Page) {
        self.drop_page(page);
    }

    fn select_victim(&mut self, _mem: &DeviceMemory) -> Option<Page> {
        self.by_tick.values().next().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::DeviceMemory;

    fn acc(page: Page) -> Access {
        Access { page, pc: 0, tb: 0, kernel: 0, inst_gap: 0, is_write: false }
    }

    #[test]
    fn evicts_least_recently_used() {
        let mem = DeviceMemory::new(16);
        let mut lru = Lru::new();
        for p in [1, 2, 3] {
            lru.on_migrate(p, false);
        }
        lru.on_access(&acc(1), true); // refresh 1
        assert_eq!(lru.select_victim(&mem), Some(2));
        lru.on_evict(2);
        assert_eq!(lru.select_victim(&mem), Some(3));
    }

    #[test]
    fn eviction_untracks() {
        let mem = DeviceMemory::new(16);
        let mut lru = Lru::new();
        lru.on_migrate(9, false);
        lru.on_evict(9);
        assert_eq!(lru.select_victim(&mem), None);
        assert!(lru.is_empty());
    }

    #[test]
    fn rebump_keeps_one_entry_per_page() {
        let mut lru = Lru::new();
        for _ in 0..10 {
            lru.on_migrate(5, false);
        }
        assert_eq!(lru.len(), 1);
        assert_eq!(lru.by_tick.len(), 1);
    }
}
