//! # `uvmio::corpus` — the content-addressed trace corpus
//!
//! The whole evaluation runs on memory-access traces, and before this
//! module every consumer regenerated them from scratch: each sweep cell,
//! each experiment table, each bench called `Workload::generate` on its
//! own private copy. The corpus turns traces into first-class, cacheable,
//! importable artifacts, in four layers:
//!
//! * [`format`] — `.uvmt`, a compact versioned binary trace format
//!   (delta-encoded pages, varint fields, FNV-1a-checksummed header)
//!   with a lossless [`Trace`](crate::trace::Trace) round-trip, plus a
//!   streaming [`TraceReader`] that yields accesses in O(1) memory so a
//!   [`crate::sim::Session`] can run corpus entries larger than RAM
//!   (feed it to [`crate::sim::Session::feed_results`]).
//! * [`CorpusStore`] — a content-addressed on-disk store: one `.uvmt`
//!   per key (hash of workload × scale × seed, or of imported content),
//!   atomic temp-file-plus-rename writes, `list`/`stat`/`gc`.
//! * [`TraceCache`] — the process-wide cache handing out `Arc<Trace>`
//!   so sweep workers, the serialized artifact lane, and the `exp`
//!   harnesses share one immutable copy per (workload, scale, seed)
//!   instead of regenerating per cell; optionally store-backed so
//!   builtin-workload copies are shared across *processes* too.
//! * [`TraceSource`] / [`parse_source`] — the ingestion layer loading
//!   generator-built, corpus-stored, and imported CSV / UVM-fault-log
//!   traces uniformly, including `A+B` multi-tenant compositions via
//!   [`crate::trace::multi::interleave`].
//!
//! The CLI surface is `repro corpus <build|import|export|list|gc>` plus
//! `repro sweep --corpus DIR`; the library surface starts at
//! [`TraceCache`] (hand one to
//! [`SweepRunner::with_cache`](crate::api::SweepRunner::with_cache)).
//!
//! ```no_run
//! use std::sync::Arc;
//! use uvmio::api::{StrategyCtx, StrategyRegistry, SweepRunner, SweepSpec};
//! use uvmio::corpus::{CorpusStore, TraceCache};
//! use uvmio::trace::workloads::Workload;
//!
//! let registry = StrategyRegistry::builtin();
//! let cache = Arc::new(TraceCache::with_store(
//!     CorpusStore::open("corpus").unwrap(),
//! ));
//! let sweep = SweepSpec::new(
//!     Workload::ALL.to_vec(),
//!     registry.resolve_list("baseline,uvmsmart").unwrap(),
//! )
//! .with_seeds(vec![42, 7]);
//! let records = SweepRunner::new(&registry)
//!     .with_cache(Arc::clone(&cache))
//!     .run(&sweep, &StrategyCtx::default(), &mut [])
//!     .unwrap();
//! // every (workload, seed) trace was built exactly once:
//! assert_eq!(cache.stats().misses(), Workload::ALL.len() as u64 * 2);
//! assert_eq!(records.len(), sweep.len());
//! ```

pub mod cache;
pub mod format;
pub mod import;
pub mod keydir;
pub mod source;
pub mod store;

pub use cache::{CacheStats, TraceCache};
pub use format::{TraceReader, UvmtMeta};
pub use keydir::{GcReport, KeyedDir, GC_TMP_GRACE};
pub use source::{
    parse_source, parse_tenants, CorpusSource, CsvSource, FaultLogSource,
    GeneratorSource, InterleaveSource, TraceSource,
};
pub use store::{CorpusEntry, CorpusStore};
