//! `.uvmt` — the corpus's compact, versioned, checksummed binary trace
//! format.
//!
//! Layout (all integers little-endian in the fixed header, LEB128
//! varints in the body):
//!
//! ```text
//! [0..4)   magic  "UVMT"
//! [4..6)   format version (u16, currently 1)
//! [6..8)   reserved (0)
//! [8..16)  FNV-1a 64 checksum of the body (u64)
//! [16..24) body length in bytes (u64)
//! [24..)   body:
//!   key                 vstr  — store key / provenance label
//!   name                vstr  — Trace::name
//!   working_set_pages   varint
//!   touched_pages       varint
//!   kernels             varint
//!   allocations         varint count, then (base varint, pages varint) each
//!   n_accesses          varint
//!   accesses, delta-encoded per access:
//!     zigzag(page  - prev_page)      varint
//!     zigzag(pc    - prev_pc)        varint
//!     zigzag(tb    - prev_tb)        varint
//!     zigzag(kernel - prev_kernel)   varint
//!     (inst_gap << 1) | is_write     varint
//! ```
//!
//! Delta-encoding pages and varint-packing every field exploits the
//! spatial locality the whole paper is about: streaming workloads
//! compress to a few bytes per access vs the 32-byte in-memory
//! [`Access`].
//! [`decode`] is the exact inverse of [`encode`] — the round-trip is
//! lossless for every field of [`Trace`], including the allocation map
//! the prefetcher relies on. A flipped bit anywhere in the body fails
//! the checksum; a truncated file fails the length check; a future
//! on-disk revision bumps `VERSION` and old readers reject it cleanly.

use anyhow::{anyhow, bail, Result};

use crate::trace::{Access, Trace};
use crate::util::hash::fnv1a64;

/// File magic: "UVMT".
pub const MAGIC: [u8; 4] = *b"UVMT";
/// On-disk format version this build reads and writes.
pub const VERSION: u16 = 1;
/// Fixed container header size (magic + version + reserved + checksum +
/// body length).
pub const HEADER_LEN: usize = 24;

// ---- varint / zigzag primitives -------------------------------------------

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v: u64 = 0;
    let mut shift: u32 = 0;
    loop {
        let b = *buf
            .get(*pos)
            .ok_or_else(|| anyhow!("uvmt: truncated varint at byte {}", *pos))?;
        *pos += 1;
        if shift > 63 {
            bail!("uvmt: varint wider than 64 bits at byte {}", *pos);
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

fn zigzag(v: i64) -> u64 {
    (v.wrapping_shl(1) ^ (v >> 63)) as u64
}

fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

fn put_vstr(out: &mut Vec<u8>, s: &str) {
    put_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn get_vstr(buf: &[u8], pos: &mut usize) -> Result<String> {
    let len = get_varint(buf, pos)? as usize;
    let end = pos
        .checked_add(len)
        .filter(|&e| e <= buf.len())
        .ok_or_else(|| anyhow!("uvmt: truncated string at byte {}", *pos))?;
    let s = std::str::from_utf8(&buf[*pos..end])
        .map_err(|e| anyhow!("uvmt: invalid utf-8 in string: {e}"))?
        .to_string();
    *pos = end;
    Ok(s)
}

// ---- metadata --------------------------------------------------------------

/// The header-level facts of a `.uvmt` file — everything `corpus list`
/// shows without decoding the access stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UvmtMeta {
    /// store key / provenance label (`gen:ATAX:s1:r42`, `import:…`)
    pub key: String,
    /// `Trace::name`
    pub name: String,
    pub working_set_pages: u64,
    pub touched_pages: u64,
    pub kernels: u32,
    pub allocations: Vec<(u64, u64)>,
    /// access count
    pub accesses: u64,
}

// ---- encode ----------------------------------------------------------------

fn encode_body(trace: &Trace, key: &str) -> Vec<u8> {
    // ~3 bytes/access is a generous steady-state estimate
    let mut b = Vec::with_capacity(64 + key.len() + trace.accesses.len() * 3);
    put_vstr(&mut b, key);
    put_vstr(&mut b, &trace.name);
    put_varint(&mut b, trace.working_set_pages);
    put_varint(&mut b, trace.touched_pages);
    put_varint(&mut b, trace.kernels as u64);
    put_varint(&mut b, trace.allocations.len() as u64);
    for &(base, pages) in &trace.allocations {
        put_varint(&mut b, base);
        put_varint(&mut b, pages);
    }
    put_varint(&mut b, trace.accesses.len() as u64);
    let (mut page, mut pc, mut tb, mut kernel) = (0u64, 0u32, 0u32, 0u32);
    for a in &trace.accesses {
        put_varint(&mut b, zigzag(a.page as i64 - page as i64));
        put_varint(&mut b, zigzag(a.pc as i64 - pc as i64));
        put_varint(&mut b, zigzag(a.tb as i64 - tb as i64));
        put_varint(&mut b, zigzag(a.kernel as i64 - kernel as i64));
        put_varint(&mut b, ((a.inst_gap as u64) << 1) | (a.is_write as u64));
        page = a.page;
        pc = a.pc;
        tb = a.tb;
        kernel = a.kernel;
    }
    b
}

/// Serialize a trace (with its store key) to `.uvmt` bytes.
pub fn encode(trace: &Trace, key: &str) -> Vec<u8> {
    let body = encode_body(trace, key);
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&fnv1a64(&body).to_le_bytes());
    out.extend_from_slice(&(body.len() as u64).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

// ---- decode ----------------------------------------------------------------

/// Verify the container (magic, version, length, checksum) and return
/// the body slice.
fn checked_body(bytes: &[u8]) -> Result<&[u8]> {
    if bytes.len() < HEADER_LEN {
        bail!("uvmt: file shorter than the {HEADER_LEN}-byte header");
    }
    if bytes[0..4] != MAGIC {
        bail!("uvmt: bad magic (not a .uvmt file)");
    }
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    if version != VERSION {
        bail!("uvmt: unsupported format version {version} (this build reads {VERSION})");
    }
    let checksum = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let body_len = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let body = &bytes[HEADER_LEN..];
    if body_len != body.len() as u64 {
        bail!(
            "uvmt: body length mismatch (header says {body_len}, file has {})",
            body.len()
        );
    }
    let actual = fnv1a64(body);
    if actual != checksum {
        bail!(
            "uvmt: checksum mismatch (header {checksum:016x}, body {actual:016x}) — corrupt file"
        );
    }
    Ok(body)
}

fn parse_meta(body: &[u8], pos: &mut usize) -> Result<UvmtMeta> {
    let key = get_vstr(body, pos)?;
    let name = get_vstr(body, pos)?;
    let working_set_pages = get_varint(body, pos)?;
    let touched_pages = get_varint(body, pos)?;
    let kernels_raw = get_varint(body, pos)?;
    let kernels = u32::try_from(kernels_raw)
        .map_err(|_| anyhow!("uvmt: kernel count {kernels_raw} exceeds u32"))?;
    let n_allocs = get_varint(body, pos)? as usize;
    // cap pre-allocation: a corrupt count must not OOM the reader
    let mut allocations = Vec::with_capacity(n_allocs.min(4096));
    for _ in 0..n_allocs {
        let base = get_varint(body, pos)?;
        let pages = get_varint(body, pos)?;
        allocations.push((base, pages));
    }
    let accesses = get_varint(body, pos)?;
    Ok(UvmtMeta {
        key,
        name,
        working_set_pages,
        touched_pages,
        kernels,
        allocations,
        accesses,
    })
}

/// Read only the metadata of a `.uvmt` byte buffer (container checks
/// included — `stat` on a corrupt file is an error, which is what lets
/// `corpus gc` find torn writes).
pub fn stat(bytes: &[u8]) -> Result<UvmtMeta> {
    let body = checked_body(bytes)?;
    let mut pos = 0usize;
    parse_meta(body, &mut pos)
}

/// Decode a `.uvmt` byte buffer back into the trace and its store key.
/// Exact inverse of [`encode`].
pub fn decode(bytes: &[u8]) -> Result<(Trace, String)> {
    let body = checked_body(bytes)?;
    let mut pos = 0usize;
    let meta = parse_meta(body, &mut pos)?;
    let n = usize::try_from(meta.accesses)
        .map_err(|_| anyhow!("uvmt: access count {} exceeds usize", meta.accesses))?;
    let mut accesses = Vec::with_capacity(n.min(1 << 24));
    let (mut page, mut pc, mut tb, mut kernel) = (0i64, 0i64, 0i64, 0i64);
    for i in 0..n {
        let dp = unzigzag(get_varint(body, &mut pos)?);
        let dpc = unzigzag(get_varint(body, &mut pos)?);
        let dtb = unzigzag(get_varint(body, &mut pos)?);
        let dk = unzigzag(get_varint(body, &mut pos)?);
        let gw = get_varint(body, &mut pos)?;
        // checked arithmetic: corrupt deltas must error, not wrap (or
        // panic the debug build)
        let bad = || anyhow!("uvmt: access {i} field overflow");
        page = page.checked_add(dp).ok_or_else(bad)?;
        pc = pc.checked_add(dpc).ok_or_else(bad)?;
        tb = tb.checked_add(dtb).ok_or_else(bad)?;
        kernel = kernel.checked_add(dk).ok_or_else(bad)?;
        if page < 0 {
            bail!("uvmt: access {i} decodes to a negative page");
        }
        let inst_gap = u32::try_from(gw >> 1)
            .map_err(|_| anyhow!("uvmt: access {i} inst_gap exceeds u32"))?;
        accesses.push(Access {
            page: page as u64,
            pc: u32::try_from(pc)
                .map_err(|_| anyhow!("uvmt: access {i} pc out of range"))?,
            tb: u32::try_from(tb)
                .map_err(|_| anyhow!("uvmt: access {i} tb out of range"))?,
            kernel: u32::try_from(kernel)
                .map_err(|_| anyhow!("uvmt: access {i} kernel out of range"))?,
            inst_gap,
            is_write: gw & 1 == 1,
        });
    }
    if pos != body.len() {
        bail!(
            "uvmt: {} trailing byte(s) after the access stream",
            body.len() - pos
        );
    }
    let trace = Trace {
        name: meta.name,
        working_set_pages: meta.working_set_pages,
        touched_pages: meta.touched_pages,
        allocations: meta.allocations,
        kernels: meta.kernels,
        accesses,
    };
    Ok((trace, meta.key))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scale;
    use crate::trace::workloads::Workload;

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        for &v in &vals {
            put_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn encode_decode_roundtrip_one_workload() {
        let t = Workload::Nw.generate(Scale::default(), 42);
        let bytes = encode(&t, "gen:NW:s1:r42");
        let (back, key) = decode(&bytes).unwrap();
        assert_eq!(key, "gen:NW:s1:r42");
        assert_eq!(back, t);
    }

    #[test]
    fn stat_reads_meta_without_decoding() {
        let t = Workload::Hotspot.generate(Scale::default(), 42);
        let bytes = encode(&t, "k");
        let m = stat(&bytes).unwrap();
        assert_eq!(m.name, t.name);
        assert_eq!(m.accesses, t.accesses.len() as u64);
        assert_eq!(m.allocations, t.allocations);
        assert_eq!(m.kernels, t.kernels);
    }

    #[test]
    fn corruption_is_detected() {
        let t = Workload::Atax.generate(Scale::default(), 7);
        let bytes = encode(&t, "k");
        // flipped magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(decode(&bad).unwrap_err().to_string().contains("magic"));
        // unsupported version
        let mut bad = bytes.clone();
        bad[4] = 0xff;
        assert!(decode(&bad).unwrap_err().to_string().contains("version"));
        // flipped body bit -> checksum
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        assert!(decode(&bad).unwrap_err().to_string().contains("checksum"));
        // truncation -> length mismatch
        let bad = &bytes[..bytes.len() - 3];
        assert!(decode(bad).unwrap_err().to_string().contains("length"));
        // header-only file
        assert!(decode(&bytes[..10]).is_err());
    }

    #[test]
    fn compression_beats_in_memory_size() {
        let t = Workload::StreamTriad.generate(Scale::default(), 42);
        let bytes = encode(&t, "k");
        let in_memory = t.accesses.len() * std::mem::size_of::<Access>();
        assert!(
            bytes.len() * 3 < in_memory,
            "uvmt {} bytes vs in-memory {in_memory}",
            bytes.len()
        );
    }
}
