//! `.uvmt` — the corpus's compact, versioned, checksummed binary trace
//! format.
//!
//! Layout (all integers little-endian in the fixed header, LEB128
//! varints in the body):
//!
//! ```text
//! [0..4)   magic  "UVMT"
//! [4..6)   format version (u16, currently 1)
//! [6..8)   reserved (0)
//! [8..16)  FNV-1a 64 checksum of the body (u64)
//! [16..24) body length in bytes (u64)
//! [24..)   body:
//!   key                 vstr  — store key / provenance label
//!   name                vstr  — Trace::name
//!   working_set_pages   varint
//!   touched_pages       varint
//!   kernels             varint
//!   allocations         varint count, then (base varint, pages varint) each
//!   n_accesses          varint
//!   accesses, delta-encoded per access:
//!     zigzag(page  - prev_page)      varint
//!     zigzag(pc    - prev_pc)        varint
//!     zigzag(tb    - prev_tb)        varint
//!     zigzag(kernel - prev_kernel)   varint
//!     (inst_gap << 1) | is_write     varint
//! ```
//!
//! Delta-encoding pages and varint-packing every field exploits the
//! spatial locality the whole paper is about: streaming workloads
//! compress to a few bytes per access vs the 32-byte in-memory
//! [`Access`].
//! [`decode`] is the exact inverse of [`encode`] — the round-trip is
//! lossless for every field of [`Trace`], including the allocation map
//! the prefetcher relies on. A flipped bit anywhere in the body fails
//! the checksum; a truncated file fails the length check; a future
//! on-disk revision bumps `VERSION` and old readers reject it cleanly.
//!
//! Two decode paths share the format:
//!
//! * [`decode`] — materializing: container checks up front (checksum
//!   verified before any access is produced), returns a full [`Trace`].
//! * [`TraceReader`] — streaming: yields accesses one at a time in O(1)
//!   memory, so a [`crate::sim::Session`] can run a corpus entry whose
//!   decoded access vector would not fit in RAM. The checksum is
//!   accumulated incrementally and verified when the stream ends — a
//!   corrupt file errors at the corrupt byte or at end-of-stream, never
//!   silently completes.

use std::io::Read;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::trace::{Access, Trace};
use crate::util::hash::{fnv1a64, Fnv1a64};

/// File magic: "UVMT".
pub const MAGIC: [u8; 4] = *b"UVMT";
/// On-disk format version this build reads and writes.
pub const VERSION: u16 = 1;
/// Fixed container header size (magic + version + reserved + checksum +
/// body length).
pub const HEADER_LEN: usize = 24;

// ---- varint / zigzag primitives -------------------------------------------

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v: u64 = 0;
    let mut shift: u32 = 0;
    loop {
        let b = *buf
            .get(*pos)
            .ok_or_else(|| anyhow!("uvmt: truncated varint at byte {}", *pos))?;
        *pos += 1;
        if shift > 63 {
            bail!("uvmt: varint wider than 64 bits at byte {}", *pos);
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

fn zigzag(v: i64) -> u64 {
    (v.wrapping_shl(1) ^ (v >> 63)) as u64
}

fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

fn put_vstr(out: &mut Vec<u8>, s: &str) {
    put_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn get_vstr(buf: &[u8], pos: &mut usize) -> Result<String> {
    let len = get_varint(buf, pos)? as usize;
    let end = pos
        .checked_add(len)
        .filter(|&e| e <= buf.len())
        .ok_or_else(|| anyhow!("uvmt: truncated string at byte {}", *pos))?;
    let s = std::str::from_utf8(&buf[*pos..end])
        .map_err(|e| anyhow!("uvmt: invalid utf-8 in string: {e}"))?
        .to_string();
    *pos = end;
    Ok(s)
}

// ---- metadata --------------------------------------------------------------

/// The header-level facts of a `.uvmt` file — everything `corpus list`
/// shows without decoding the access stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UvmtMeta {
    /// store key / provenance label (`gen:ATAX:s1:r42`, `import:…`)
    pub key: String,
    /// `Trace::name`
    pub name: String,
    pub working_set_pages: u64,
    pub touched_pages: u64,
    pub kernels: u32,
    pub allocations: Vec<(u64, u64)>,
    /// access count
    pub accesses: u64,
}

// ---- encode ----------------------------------------------------------------

fn encode_body(trace: &Trace, key: &str) -> Vec<u8> {
    // ~3 bytes/access is a generous steady-state estimate
    let mut b = Vec::with_capacity(64 + key.len() + trace.accesses.len() * 3);
    put_vstr(&mut b, key);
    put_vstr(&mut b, &trace.name);
    put_varint(&mut b, trace.working_set_pages);
    put_varint(&mut b, trace.touched_pages);
    put_varint(&mut b, trace.kernels as u64);
    put_varint(&mut b, trace.allocations.len() as u64);
    for &(base, pages) in &trace.allocations {
        put_varint(&mut b, base);
        put_varint(&mut b, pages);
    }
    put_varint(&mut b, trace.accesses.len() as u64);
    let (mut page, mut pc, mut tb, mut kernel) = (0u64, 0u32, 0u32, 0u32);
    for a in &trace.accesses {
        put_varint(&mut b, zigzag(a.page as i64 - page as i64));
        put_varint(&mut b, zigzag(a.pc as i64 - pc as i64));
        put_varint(&mut b, zigzag(a.tb as i64 - tb as i64));
        put_varint(&mut b, zigzag(a.kernel as i64 - kernel as i64));
        put_varint(&mut b, ((a.inst_gap as u64) << 1) | (a.is_write as u64));
        page = a.page;
        pc = a.pc;
        tb = a.tb;
        kernel = a.kernel;
    }
    b
}

/// Serialize a trace (with its store key) to `.uvmt` bytes.
pub fn encode(trace: &Trace, key: &str) -> Vec<u8> {
    let body = encode_body(trace, key);
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&fnv1a64(&body).to_le_bytes());
    out.extend_from_slice(&(body.len() as u64).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

// ---- decode ----------------------------------------------------------------

/// Little-endian u64 at `b[at..at + 8]`. Callers bound-check `b` first;
/// spelled as an explicit byte gather so corrupt-input paths stay free
/// of unwraps (unwrap-ratchet).
fn le_u64(b: &[u8], at: usize) -> u64 {
    u64::from_le_bytes([
        b[at],
        b[at + 1],
        b[at + 2],
        b[at + 3],
        b[at + 4],
        b[at + 5],
        b[at + 6],
        b[at + 7],
    ])
}

/// Validate the fixed header and extract `(checksum, body_len)`.
fn parse_header(header: &[u8]) -> Result<(u64, u64)> {
    if header.len() < HEADER_LEN {
        bail!("uvmt: file shorter than the {HEADER_LEN}-byte header");
    }
    if header[0..4] != MAGIC {
        bail!("uvmt: bad magic (not a .uvmt file)");
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != VERSION {
        bail!("uvmt: unsupported format version {version} (this build reads {VERSION})");
    }
    let checksum = le_u64(header, 8);
    let body_len = le_u64(header, 16);
    Ok((checksum, body_len))
}

/// Verify the container (magic, version, length, checksum) and return
/// the body slice.
fn checked_body(bytes: &[u8]) -> Result<&[u8]> {
    let (checksum, body_len) = parse_header(bytes)?;
    let body = &bytes[HEADER_LEN..];
    if body_len != body.len() as u64 {
        bail!(
            "uvmt: body length mismatch (header says {body_len}, file has {})",
            body.len()
        );
    }
    let actual = fnv1a64(body);
    if actual != checksum {
        bail!(
            "uvmt: checksum mismatch (header {checksum:016x}, body {actual:016x}) — corrupt file"
        );
    }
    Ok(body)
}

fn parse_meta(body: &[u8], pos: &mut usize) -> Result<UvmtMeta> {
    let key = get_vstr(body, pos)?;
    let name = get_vstr(body, pos)?;
    let working_set_pages = get_varint(body, pos)?;
    let touched_pages = get_varint(body, pos)?;
    let kernels_raw = get_varint(body, pos)?;
    let kernels = u32::try_from(kernels_raw)
        .map_err(|_| anyhow!("uvmt: kernel count {kernels_raw} exceeds u32"))?;
    let n_allocs = get_varint(body, pos)? as usize;
    // cap pre-allocation: a corrupt count must not OOM the reader
    let mut allocations = Vec::with_capacity(n_allocs.min(4096));
    for _ in 0..n_allocs {
        let base = get_varint(body, pos)?;
        let pages = get_varint(body, pos)?;
        allocations.push((base, pages));
    }
    let accesses = get_varint(body, pos)?;
    Ok(UvmtMeta {
        key,
        name,
        working_set_pages,
        touched_pages,
        kernels,
        allocations,
        accesses,
    })
}

/// Read only the metadata of a `.uvmt` byte buffer (container checks
/// included — `stat` on a corrupt file is an error, which is what lets
/// `corpus gc` find torn writes).
pub fn stat(bytes: &[u8]) -> Result<UvmtMeta> {
    let body = checked_body(bytes)?;
    let mut pos = 0usize;
    parse_meta(body, &mut pos)
}

/// Decode a `.uvmt` byte buffer back into the trace and its store key.
/// Exact inverse of [`encode`]. Container checks (magic, version,
/// length, checksum) run up front so corruption fails fast; the access
/// loop then delegates to the same [`TraceReader`] the streaming path
/// uses — one decoder, two entry points, no drift between them.
pub fn decode(bytes: &[u8]) -> Result<(Trace, String)> {
    checked_body(bytes)?;
    let mut reader = TraceReader::new(std::io::Cursor::new(bytes))?;
    let n = usize::try_from(reader.meta().accesses).map_err(|_| {
        anyhow!("uvmt: access count {} exceeds usize", reader.meta().accesses)
    })?;
    let mut accesses = Vec::with_capacity(n.min(1 << 24));
    while let Some(a) = reader.next_access()? {
        accesses.push(a);
    }
    let meta = reader.into_meta();
    let trace = Trace {
        name: meta.name,
        working_set_pages: meta.working_set_pages,
        touched_pages: meta.touched_pages,
        allocations: meta.allocations,
        kernels: meta.kernels,
        accesses,
    };
    Ok((trace, meta.key))
}

// ---- streaming decode ------------------------------------------------------

/// Body-byte source for the streaming reader: pulls from the underlying
/// `Read`, feeds every byte through the running FNV-1a digest, and
/// enforces the header-declared body length.
struct BodyReader<R: Read> {
    src: R,
    hasher: Fnv1a64,
    consumed: u64,
    len: u64,
}

impl<R: Read> BodyReader<R> {
    fn byte(&mut self) -> Result<u8> {
        if self.consumed >= self.len {
            bail!(
                "uvmt: body ended at byte {} but more data was expected \
                 (header-declared length too short or file corrupt)",
                self.consumed
            );
        }
        let mut b = [0u8; 1];
        self.src.read_exact(&mut b).map_err(|e| {
            anyhow!("uvmt: truncated body at byte {}: {e}", self.consumed)
        })?;
        self.hasher.update(&b);
        self.consumed += 1;
        Ok(b[0])
    }

    fn varint(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        let mut shift: u32 = 0;
        loop {
            let b = self.byte()?;
            if shift > 63 {
                bail!("uvmt: varint wider than 64 bits at byte {}", self.consumed);
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    fn vstr(&mut self) -> Result<String> {
        let len = self.varint()? as usize;
        if (len as u64) > self.len.saturating_sub(self.consumed) {
            bail!("uvmt: truncated string at byte {}", self.consumed);
        }
        let mut buf = vec![0u8; len];
        for slot in buf.iter_mut() {
            *slot = self.byte()?;
        }
        String::from_utf8(buf)
            .map_err(|e| anyhow!("uvmt: invalid utf-8 in string: {e}"))
    }

    /// End-of-stream checks: every declared body byte consumed and the
    /// accumulated digest matches the header checksum.
    fn verify_end(&mut self, expect_checksum: u64) -> Result<()> {
        if self.consumed != self.len {
            bail!(
                "uvmt: {} trailing byte(s) after the access stream",
                self.len - self.consumed
            );
        }
        let actual = self.hasher.digest();
        if actual != expect_checksum {
            bail!(
                "uvmt: checksum mismatch (header {expect_checksum:016x}, \
                 body {actual:016x}) — corrupt file"
            );
        }
        Ok(())
    }
}

/// Streaming `.uvmt` decoder: parses the header and metadata up front,
/// then yields [`Access`]es one at a time without ever materializing the
/// access vector — O(1) memory regardless of trace length, which is what
/// lets a [`crate::sim::Session`] run corpus entries larger than RAM.
///
/// Integrity: the body checksum is accumulated as bytes stream through
/// and verified when the last access is yielded (or when the iterator is
/// polled past the end). Corruption therefore surfaces as an `Err` at
/// the corrupt byte or at end-of-stream — a fully consumed, error-free
/// stream carries exactly the same guarantee as [`decode`].
///
/// Implements `Iterator<Item = Result<Access>>` (fused after the first
/// error), so it plugs straight into
/// [`crate::sim::Session::feed_results`].
pub struct TraceReader<R: Read> {
    body: BodyReader<R>,
    meta: UvmtMeta,
    checksum: u64,
    /// accesses not yet yielded
    remaining: u64,
    prev: [i64; 4],
    /// end-of-stream verification already performed
    verified: bool,
    /// a decode error was returned; the stream is fused
    failed: bool,
}

impl TraceReader<std::io::BufReader<std::fs::File>> {
    /// Open a `.uvmt` file for streaming (buffered).
    pub fn open(path: &Path) -> Result<Self> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        TraceReader::new(std::io::BufReader::new(f))
            .with_context(|| format!("reading {}", path.display()))
    }
}

impl<R: Read> TraceReader<R> {
    /// Wrap any byte source; validates the container header and parses
    /// the metadata immediately (so [`TraceReader::meta`] is available
    /// before the first access is pulled).
    pub fn new(mut src: R) -> Result<TraceReader<R>> {
        let mut header = [0u8; HEADER_LEN];
        src.read_exact(&mut header).map_err(|e| {
            anyhow!("uvmt: file shorter than the {HEADER_LEN}-byte header: {e}")
        })?;
        let (checksum, body_len) = parse_header(&header)?;
        let mut body =
            BodyReader { src, hasher: Fnv1a64::new(), consumed: 0, len: body_len };
        let key = body.vstr()?;
        let name = body.vstr()?;
        let working_set_pages = body.varint()?;
        let touched_pages = body.varint()?;
        let kernels_raw = body.varint()?;
        let kernels = u32::try_from(kernels_raw)
            .map_err(|_| anyhow!("uvmt: kernel count {kernels_raw} exceeds u32"))?;
        let n_allocs = body.varint()? as usize;
        // cap pre-allocation: a corrupt count must not OOM the reader
        let mut allocations = Vec::with_capacity(n_allocs.min(4096));
        for _ in 0..n_allocs {
            let base = body.varint()?;
            let pages = body.varint()?;
            allocations.push((base, pages));
        }
        let accesses = body.varint()?;
        let meta = UvmtMeta {
            key,
            name,
            working_set_pages,
            touched_pages,
            kernels,
            allocations,
            accesses,
        };
        Ok(TraceReader {
            body,
            remaining: meta.accesses,
            meta,
            checksum,
            prev: [0; 4],
            verified: false,
            failed: false,
        })
    }

    /// Header-level metadata (available before any access is decoded).
    pub fn meta(&self) -> &UvmtMeta {
        &self.meta
    }

    /// Consume the reader, keeping its metadata (e.g. after draining
    /// the access stream).
    pub fn into_meta(self) -> UvmtMeta {
        self.meta
    }

    /// Accesses not yet yielded.
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// Decode the next access; `Ok(None)` at a (verified) end of stream.
    pub fn next_access(&mut self) -> Result<Option<Access>> {
        if self.failed {
            return Ok(None);
        }
        match self.next_inner() {
            Ok(v) => Ok(v),
            Err(e) => {
                self.failed = true;
                Err(e)
            }
        }
    }

    fn next_inner(&mut self) -> Result<Option<Access>> {
        if self.remaining == 0 {
            if !self.verified {
                self.verified = true;
                self.body.verify_end(self.checksum)?;
            }
            return Ok(None);
        }
        let i = self.meta.accesses - self.remaining;
        let dp = unzigzag(self.body.varint()?);
        let dpc = unzigzag(self.body.varint()?);
        let dtb = unzigzag(self.body.varint()?);
        let dk = unzigzag(self.body.varint()?);
        let gw = self.body.varint()?;
        // checked arithmetic: corrupt deltas must error, not wrap
        let bad = || anyhow!("uvmt: access {i} field overflow");
        let [page, pc, tb, kernel] = &mut self.prev;
        *page = page.checked_add(dp).ok_or_else(bad)?;
        *pc = pc.checked_add(dpc).ok_or_else(bad)?;
        *tb = tb.checked_add(dtb).ok_or_else(bad)?;
        *kernel = kernel.checked_add(dk).ok_or_else(bad)?;
        if *page < 0 {
            bail!("uvmt: access {i} decodes to a negative page");
        }
        let inst_gap = u32::try_from(gw >> 1)
            .map_err(|_| anyhow!("uvmt: access {i} inst_gap exceeds u32"))?;
        let access = Access {
            page: *page as u64,
            pc: u32::try_from(*pc)
                .map_err(|_| anyhow!("uvmt: access {i} pc out of range"))?,
            tb: u32::try_from(*tb)
                .map_err(|_| anyhow!("uvmt: access {i} tb out of range"))?,
            kernel: u32::try_from(*kernel)
                .map_err(|_| anyhow!("uvmt: access {i} kernel out of range"))?,
            inst_gap,
            is_write: gw & 1 == 1,
        };
        self.remaining -= 1;
        if self.remaining == 0 {
            // verify eagerly so a fully drained `for` loop cannot miss a
            // bad checksum by never polling past the last item
            self.verified = true;
            self.body.verify_end(self.checksum)?;
        }
        Ok(Some(access))
    }
}

impl<R: Read> Iterator for TraceReader<R> {
    type Item = Result<Access>;

    fn next(&mut self) -> Option<Self::Item> {
        self.next_access().transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scale;
    use crate::trace::workloads::Workload;

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        for &v in &vals {
            put_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn encode_decode_roundtrip_one_workload() {
        let t = Workload::Nw.generate(Scale::default(), 42);
        let bytes = encode(&t, "gen:NW:s1:r42");
        let (back, key) = decode(&bytes).unwrap();
        assert_eq!(key, "gen:NW:s1:r42");
        assert_eq!(back, t);
    }

    #[test]
    fn stat_reads_meta_without_decoding() {
        let t = Workload::Hotspot.generate(Scale::default(), 42);
        let bytes = encode(&t, "k");
        let m = stat(&bytes).unwrap();
        assert_eq!(m.name, t.name);
        assert_eq!(m.accesses, t.accesses.len() as u64);
        assert_eq!(m.allocations, t.allocations);
        assert_eq!(m.kernels, t.kernels);
    }

    #[test]
    fn corruption_is_detected() {
        let t = Workload::Atax.generate(Scale::default(), 7);
        let bytes = encode(&t, "k");
        // flipped magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(decode(&bad).unwrap_err().to_string().contains("magic"));
        // unsupported version
        let mut bad = bytes.clone();
        bad[4] = 0xff;
        assert!(decode(&bad).unwrap_err().to_string().contains("version"));
        // flipped body bit -> checksum
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        assert!(decode(&bad).unwrap_err().to_string().contains("checksum"));
        // truncation -> length mismatch
        let bad = &bytes[..bytes.len() - 3];
        assert!(decode(bad).unwrap_err().to_string().contains("length"));
        // header-only file
        assert!(decode(&bytes[..10]).is_err());
    }

    #[test]
    fn streaming_reader_matches_materialized_decode() {
        let t = Workload::Bicg.generate(Scale::default(), 42);
        let bytes = encode(&t, "gen:BICG:s1:r42");
        let mut r = TraceReader::new(std::io::Cursor::new(&bytes)).unwrap();
        assert_eq!(r.meta().key, "gen:BICG:s1:r42");
        assert_eq!(r.meta().name, t.name);
        assert_eq!(r.meta().accesses, t.accesses.len() as u64);
        assert_eq!(r.meta().allocations, t.allocations);
        assert_eq!(r.remaining(), t.accesses.len() as u64);
        let mut streamed = Vec::new();
        while let Some(a) = r.next_access().unwrap() {
            streamed.push(a);
        }
        assert_eq!(streamed, t.accesses);
        assert_eq!(r.remaining(), 0);
        // polling past the end keeps returning a clean None
        assert!(r.next_access().unwrap().is_none());
    }

    #[test]
    fn streaming_reader_iterator_interface() {
        let t = Workload::Hotspot.generate(Scale::default(), 7);
        let bytes = encode(&t, "k");
        let r = TraceReader::new(std::io::Cursor::new(&bytes)).unwrap();
        let streamed: Result<Vec<Access>> = r.collect();
        assert_eq!(streamed.unwrap(), t.accesses);
    }

    #[test]
    fn streaming_reader_detects_corruption() {
        let t = Workload::Atax.generate(Scale::default(), 7);
        let bytes = encode(&t, "k");

        // flipped final body bit: every access decodes, checksum fails
        // at end-of-stream — the error cannot be missed by a drain loop
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        let mut r = TraceReader::new(std::io::Cursor::new(&bad)).unwrap();
        let mut err = None;
        while err.is_none() {
            match r.next_access() {
                Ok(Some(_)) => {}
                Ok(None) => panic!("corrupt stream completed cleanly"),
                Err(e) => err = Some(e.to_string()),
            }
        }
        assert!(err.unwrap().contains("checksum"));
        // the iterator is fused after the error
        assert!(r.next_access().unwrap().is_none());

        // truncation: read_exact fails mid-stream
        let cut = &bytes[..bytes.len() - 3];
        let mut r = TraceReader::new(std::io::Cursor::new(cut)).unwrap();
        let mut saw_err = false;
        for item in &mut r {
            if let Err(e) = item {
                assert!(e.to_string().contains("truncated"), "{e}");
                saw_err = true;
                break;
            }
        }
        assert!(saw_err);

        // bad magic / short header fail at construction
        assert!(TraceReader::new(std::io::Cursor::new(&bytes[..10])).is_err());
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(TraceReader::new(std::io::Cursor::new(&bad)).is_err());
    }

    #[test]
    fn compression_beats_in_memory_size() {
        let t = Workload::StreamTriad.generate(Scale::default(), 42);
        let bytes = encode(&t, "k");
        let in_memory = t.accesses.len() * std::mem::size_of::<Access>();
        assert!(
            bytes.len() * 3 < in_memory,
            "uvmt {} bytes vs in-memory {in_memory}",
            bytes.len()
        );
    }
}
