//! External-trace ingestion: parse CSV page-access dumps and UVM
//! fault-log text into [`Trace`]s the simulator can run.
//!
//! Two text formats are accepted:
//!
//! * **CSV** (`page,pc,tb,kernel,inst_gap,is_write` — any column order,
//!   headers required, all but `page` optional): the lossless
//!   interchange format. This is what another simulator, a GPGPU-Sim
//!   hook, or a spreadsheet of hand-written accesses exports.
//! * **UVM fault log** (`[timestamp-µs] address [r|w]` per line, `#`
//!   comments): the shape of real `nvidia-uvm` fault captures used by
//!   the UVM-prefetching literature. Addresses are page-aligned and
//!   rebased so the lowest page is 0; timestamps (when present) become
//!   `inst_gap` via the Table V clock, so the timing model sees the
//!   log's real inter-fault gaps.
//!
//! Both parsers reject non-monotone kernel ids and validate the
//! resulting trace before it reaches the corpus — a malformed import
//! fails loudly at `repro corpus import` time, never inside a sweep.

use std::fs;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{us_to_cycles, PAGE_SIZE};
use crate::trace::{Access, Trace};

/// Load a CSV access trace from a file. See [`parse_csv`].
pub fn csv_trace(path: &Path, name: &str) -> Result<Trace> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_csv(&text, name).with_context(|| format!("parsing {}", path.display()))
}

/// Load a UVM fault log from a file. See [`parse_uvm_fault_log`].
pub fn uvm_fault_log_trace(path: &Path, name: &str) -> Result<Trace> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_uvm_fault_log(&text, name)
        .with_context(|| format!("parsing {}", path.display()))
}

fn parse_bool(s: &str, line_no: usize) -> Result<bool> {
    match s.trim().to_ascii_lowercase().as_str() {
        "1" | "true" | "w" | "write" | "st" | "store" => Ok(true),
        "0" | "false" | "r" | "read" | "ld" | "load" | "" => Ok(false),
        other => bail!("line {line_no}: cannot parse is_write value {other:?}"),
    }
}

fn finish_trace(name: &str, mut accesses: Vec<Access>) -> Result<Trace> {
    if accesses.is_empty() {
        bail!("no accesses parsed");
    }
    let max_page = accesses.iter().map(|a| a.page).max().unwrap_or(0);
    let touched: std::collections::HashSet<u64> =
        accesses.iter().map(|a| a.page).collect();
    // guarantee the phase-count invariant Trace::validate checks even if
    // the input skipped kernel ids: compress ids to a dense 0..k range
    let mut remap: std::collections::BTreeMap<u32, u32> = Default::default();
    for a in &accesses {
        let next = remap.len() as u32;
        remap.entry(a.kernel).or_insert(next);
    }
    for a in accesses.iter_mut() {
        a.kernel = remap[&a.kernel];
    }
    let trace = Trace {
        name: name.to_string(),
        working_set_pages: max_page + 1,
        touched_pages: touched.len() as u64,
        allocations: Vec::new(), // one allocation spanning the arena
        kernels: remap.len() as u32,
        accesses,
    };
    trace.validate().map_err(|e| anyhow!("imported trace invalid: {e}"))?;
    Ok(trace)
}

/// Parse a CSV access trace. Header row is required and names the
/// columns; `page` is mandatory, `pc`/`tb`/`kernel`/`inst_gap` default
/// to 0 and `is_write` to false when absent. Kernel ids must be
/// non-decreasing (they delimit program phases).
pub fn parse_csv(text: &str, name: &str) -> Result<Trace> {
    let mut lines = text
        .lines()
        .enumerate()
        .map(|(i, l)| (i + 1, l.trim()))
        .filter(|(_, l)| !l.is_empty() && !l.starts_with('#'));
    let (_, header) = lines
        .next()
        .ok_or_else(|| anyhow!("empty file (need a header row)"))?;
    let cols: Vec<String> = header
        .split(',')
        .map(|c| c.trim().to_ascii_lowercase())
        .collect();
    let col = |want: &str| cols.iter().position(|c| c == want);
    let c_page = col("page")
        .ok_or_else(|| anyhow!("header {header:?} has no 'page' column"))?;
    let (c_pc, c_tb, c_kernel, c_gap, c_write) = (
        col("pc"),
        col("tb"),
        col("kernel"),
        col("inst_gap"),
        col("is_write"),
    );

    let mut accesses = Vec::new();
    let mut last_kernel = 0u32;
    for (line_no, line) in lines {
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        let field = |idx: Option<usize>| -> Option<&str> {
            idx.and_then(|i| fields.get(i).copied())
        };
        // u32 fields parse via u32 directly: an out-of-range value is a
        // loud per-line error, never a silent truncation
        let num32 = |idx: Option<usize>, what: &str| -> Result<u32> {
            match field(idx) {
                None | Some("") => Ok(0),
                Some(v) => v.parse::<u32>().map_err(|_| {
                    anyhow!(
                        "line {line_no}: cannot parse {what} value {v:?} \
                         (want an integer < 2^32)"
                    )
                }),
            }
        };
        let page = field(Some(c_page))
            .filter(|v| !v.is_empty())
            .ok_or_else(|| anyhow!("line {line_no}: missing page value"))?
            .parse::<u64>()
            .map_err(|_| anyhow!("line {line_no}: cannot parse page"))?;
        let kernel = num32(c_kernel, "kernel")?;
        if kernel < last_kernel {
            bail!(
                "line {line_no}: kernel id {kernel} went backwards (was {last_kernel}); \
                 kernel ids must be non-decreasing"
            );
        }
        last_kernel = kernel;
        accesses.push(Access {
            page,
            pc: num32(c_pc, "pc")?,
            tb: num32(c_tb, "tb")?,
            kernel,
            inst_gap: num32(c_gap, "inst_gap")?,
            is_write: parse_bool(field(c_write).unwrap_or("0"), line_no)?,
        });
    }
    finish_trace(name, accesses)
}

/// Parse a UVM fault log: one fault per line as
/// `[timestamp-µs] address [r|w]` (address hex `0x…` or decimal bytes;
/// lines starting with `#` are comments). Addresses are page-aligned
/// and rebased to a zero-based arena; timestamp deltas become
/// `inst_gap` cycles.
pub fn parse_uvm_fault_log(text: &str, name: &str) -> Result<Trace> {
    struct Fault {
        addr: u64,
        ts_us: Option<f64>,
        is_write: bool,
    }
    let parse_addr = |s: &str, line_no: usize| -> Result<u64> {
        let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
            Some(hex) => u64::from_str_radix(hex, 16),
            None => s.parse::<u64>(),
        };
        parsed.map_err(|_| anyhow!("line {line_no}: cannot parse address {s:?}"))
    };
    let is_rw = |s: &str| matches!(s.to_ascii_lowercase().as_str(), "r" | "w");

    let mut faults = Vec::new();
    for (line_no, raw) in text.lines().enumerate() {
        let line_no = line_no + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let tok: Vec<&str> = line.split_whitespace().collect();
        let (ts_us, addr_tok, rw_tok) = match tok.as_slice() {
            [a] => (None, *a, None),
            [a, b] if is_rw(b) => (None, *a, Some(*b)),
            [a, b] => (Some(*a), *b, None),
            [a, b, c] => (Some(*a), *b, Some(*c)),
            _ => bail!("line {line_no}: expected `[timestamp] address [r|w]`"),
        };
        let ts_us = match ts_us {
            None => None,
            Some(t) => Some(t.parse::<f64>().map_err(|_| {
                anyhow!("line {line_no}: cannot parse timestamp {t:?}")
            })?),
        };
        let is_write = match rw_tok {
            None => false,
            Some(t) => match t.to_ascii_lowercase().as_str() {
                "w" => true,
                "r" => false,
                other => bail!("line {line_no}: access kind {other:?} (want r|w)"),
            },
        };
        faults.push(Fault {
            addr: parse_addr(addr_tok, line_no)?,
            ts_us,
            is_write,
        });
    }
    if faults.is_empty() {
        bail!("no faults parsed");
    }

    let min_page = faults.iter().map(|f| f.addr / PAGE_SIZE).min().unwrap();
    let mut accesses = Vec::with_capacity(faults.len());
    let mut prev_ts: Option<f64> = None;
    for f in &faults {
        let gap_cycles = match (prev_ts, f.ts_us) {
            (Some(p), Some(t)) if t > p => us_to_cycles(t - p).min(u32::MAX as u64),
            _ => 0,
        };
        if f.ts_us.is_some() {
            prev_ts = f.ts_us;
        }
        accesses.push(Access {
            page: f.addr / PAGE_SIZE - min_page,
            pc: 0,
            tb: 0,
            kernel: 0,
            inst_gap: gap_cycles as u32,
            is_write: f.is_write,
        });
    }
    finish_trace(name, accesses)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_full_columns() {
        let text = "\
page,pc,tb,kernel,inst_gap,is_write
0,1,0,0,4,0
1,1,0,0,4,1
5,2,1,1,2,true
";
        let t = parse_csv(text, "mini").unwrap();
        assert_eq!(t.name, "mini");
        assert_eq!(t.accesses.len(), 3);
        assert_eq!(t.working_set_pages, 6);
        assert_eq!(t.touched_pages, 3);
        assert_eq!(t.kernels, 2);
        assert!(t.accesses[1].is_write);
        assert!(t.accesses[2].is_write);
        assert_eq!(t.accesses[2].kernel, 1);
        t.validate().unwrap();
    }

    #[test]
    fn csv_minimal_and_reordered_columns() {
        let t = parse_csv("is_write,page\nw,3\nr,4\n", "m").unwrap();
        assert_eq!(t.accesses.len(), 2);
        assert!(t.accesses[0].is_write);
        assert_eq!(t.accesses[1].page, 4);
        assert_eq!(t.kernels, 1);
    }

    #[test]
    fn csv_sparse_kernel_ids_are_compressed() {
        let t = parse_csv("page,kernel\n0,0\n1,5\n2,9\n", "m").unwrap();
        let ks: Vec<u32> = t.accesses.iter().map(|a| a.kernel).collect();
        assert_eq!(ks, vec![0, 1, 2]);
        assert_eq!(t.kernels, 3);
        t.validate().unwrap();
    }

    #[test]
    fn csv_rejects_backwards_kernels_and_garbage() {
        assert!(parse_csv("page,kernel\n0,1\n1,0\n", "m")
            .unwrap_err()
            .to_string()
            .contains("backwards"));
        assert!(parse_csv("pc,tb\n0,0\n", "m").is_err()); // no page column
        assert!(parse_csv("page\nxyz\n", "m").is_err());
        assert!(parse_csv("", "m").is_err());
        assert!(parse_csv("page\n", "m").is_err()); // header only
        // u32 overflow is an error, not a silent truncation
        let err = parse_csv("page,inst_gap\n0,4294967296\n", "m")
            .unwrap_err()
            .to_string();
        assert!(err.contains("inst_gap"), "{err}");
    }

    #[test]
    fn fault_log_rebases_and_times() {
        let text = "\
# ts_us address kind
10.0 0x7f0001000 r
12.0 0x7f0003000 w
15.0 0x7f0001000 r
";
        let t = parse_uvm_fault_log(text, "log").unwrap();
        assert_eq!(t.accesses.len(), 3);
        assert_eq!(t.accesses[0].page, 0);
        assert_eq!(t.accesses[1].page, 2);
        assert!(t.accesses[1].is_write);
        assert_eq!(t.accesses[0].inst_gap, 0);
        assert!(t.accesses[1].inst_gap > 0); // 2 µs of Table-V cycles
        assert_eq!(t.working_set_pages, 3);
        t.validate().unwrap();
    }

    #[test]
    fn fault_log_bare_addresses() {
        let t = parse_uvm_fault_log("4096\n8192\n4096\n", "log").unwrap();
        assert_eq!(t.accesses.len(), 3);
        assert_eq!(t.accesses[0].page, 0);
        assert_eq!(t.accesses[1].page, 1);
        assert!(parse_uvm_fault_log("", "log").is_err());
        assert!(parse_uvm_fault_log("zzz\n", "log").is_err());
    }
}
