//! `TraceCache` — the shared in-memory half of the corpus.
//!
//! Every consumer of a trace (sweep workers, the serialized artifact
//! lane, the `exp` harnesses) asks the cache instead of calling
//! `Workload::generate` directly; the cache hands out `Arc<Trace>` so
//! one immutable copy per (workload × scale × seed) is shared across
//! threads instead of being regenerated per grid cell. Optionally
//! backed by a [`CorpusStore`]: *builtin* misses are first looked up on
//! disk (`.uvmt` decode is much cheaper than regeneration for the big
//! irregular workloads) and freshly generated traces are persisted so
//! the next process shares them too. [`TraceSource`] loads (corpus
//! names, `csv:`/`uvmlog:` files, compositions) are cached in memory
//! only — corpus-named sources already read from the store, and file
//! sources re-parse their file once per process.
//!
//! Concurrency: a global map mutex held only for slot lookup, plus one
//! mutex per key held across that key's construction. Distinct traces
//! build in parallel across sweep workers, while two requests for the
//! SAME key serialize — which is what makes "each trace is built
//! exactly once" a hard guarantee ([`CacheStats::builds`] counts
//! constructions) rather than a race.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::Scale;
use crate::trace::workloads::Workload;
use crate::trace::Trace;

use super::source::TraceSource;
use super::store::CorpusStore;

/// Cache effectiveness counters (monotone since construction).
///
/// Accounting invariant: every lookup resolves to exactly one of a
/// memory hit, a build, a store load, or a failure, so at quiescence
/// (no `get_*` call in flight)
/// `hits + builds + store_loads + failures == lookups` —
/// [`CacheStats::consistent`] checks it, and the cache tests assert it.
/// Before `lookups`/`failures` existed, an errored build or a store
/// entry produced by a concurrent in-process builder could leave the
/// counters telling an incomplete story with no way to notice; the
/// invariant makes any such under-report a loud test failure.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// total `get_builtin` / `get_source` calls
    pub lookups: u64,
    /// requests served from memory (shared `Arc` handed out)
    pub hits: u64,
    /// traces constructed (generated or loaded through a source)
    pub builds: u64,
    /// misses satisfied by decoding a `.uvmt` from the backing store
    pub store_loads: u64,
    /// freshly generated traces persisted to the backing store
    pub store_writes: u64,
    /// lookups whose build/load errored (the slot stays retryable)
    pub failures: u64,
}

impl CacheStats {
    /// Total cache misses (every one produced exactly one trace).
    pub fn misses(&self) -> u64 {
        self.builds + self.store_loads
    }

    /// The accounting invariant; holds whenever no lookup is in flight.
    pub fn consistent(&self) -> bool {
        self.hits + self.builds + self.store_loads + self.failures == self.lookups
    }
}

/// One per-key slot: its mutex is held across that key's construction,
/// so the same trace is never built twice while distinct keys proceed
/// in parallel.
type Slot = Arc<Mutex<Option<Arc<Trace>>>>;

/// How a freshly constructed trace came to be (for the stats).
enum Origin {
    /// built by a generator or source load; `persisted` = also written
    /// to the backing store
    Built { persisted: bool },
    /// decoded from the backing store
    StoreLoaded,
}

/// Process-wide cache of immutable traces. `Sync`: share it by
/// reference (or `Arc`) across sweep workers.
pub struct TraceCache {
    map: Mutex<HashMap<String, Slot>>,
    store: Option<CorpusStore>,
    lookups: AtomicU64,
    hits: AtomicU64,
    builds: AtomicU64,
    store_loads: AtomicU64,
    store_writes: AtomicU64,
    failures: AtomicU64,
}

impl Default for TraceCache {
    fn default() -> Self {
        TraceCache::new()
    }
}

impl TraceCache {
    /// A purely in-memory cache.
    pub fn new() -> TraceCache {
        TraceCache {
            map: Mutex::new(HashMap::new()),
            store: None,
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            builds: AtomicU64::new(0),
            store_loads: AtomicU64::new(0),
            store_writes: AtomicU64::new(0),
            failures: AtomicU64::new(0),
        }
    }

    /// A cache backed by an on-disk corpus: builtin misses consult the
    /// store, fresh generations are persisted to it.
    pub fn with_store(store: CorpusStore) -> TraceCache {
        let mut c = TraceCache::new();
        c.store = Some(store);
        c
    }

    pub fn store(&self) -> Option<&CorpusStore> {
        self.store.as_ref()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            lookups: self.lookups.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            builds: self.builds.load(Ordering::Relaxed),
            store_loads: self.store_loads.load(Ordering::Relaxed),
            store_writes: self.store_writes.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
        }
    }

    /// Distinct trace slots currently resident.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every resident trace (outstanding `Arc`s stay alive).
    pub fn clear(&self) {
        self.map.lock().unwrap_or_else(|p| p.into_inner()).clear();
    }

    /// The slot for `key`, creating it if absent. Global lock held only
    /// for this lookup.
    fn slot(&self, key: &str) -> Slot {
        let mut map = self.map.lock().unwrap_or_else(|p| p.into_inner());
        match map.get(key) {
            Some(s) => Arc::clone(s),
            None => {
                let s = Slot::default();
                map.insert(key.to_string(), Arc::clone(&s));
                s
            }
        }
    }

    /// Hit the slot or construct via `build` with only the per-key lock
    /// held. A failed build leaves the slot empty, so a later call
    /// retries. Every path through here settles exactly one `lookups`
    /// increment into hit / build / store-load / failure — the
    /// [`CacheStats::consistent`] invariant.
    fn get_or_build(
        &self,
        key: &str,
        build: impl FnOnce() -> Result<(Trace, Origin)>,
    ) -> Result<Arc<Trace>> {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let slot = self.slot(key);
        let mut guard = slot.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(t) = guard.as_ref() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(t));
        }
        let (trace, origin) = match build() {
            Ok(v) => v,
            Err(e) => {
                self.failures.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        };
        match origin {
            Origin::Built { persisted } => {
                self.builds.fetch_add(1, Ordering::Relaxed);
                if persisted {
                    self.store_writes.fetch_add(1, Ordering::Relaxed);
                }
            }
            Origin::StoreLoaded => {
                self.store_loads.fetch_add(1, Ordering::Relaxed);
            }
        }
        let arc = Arc::new(trace);
        *guard = Some(Arc::clone(&arc));
        Ok(arc)
    }

    /// The shared trace of a builtin workload at (scale, seed) —
    /// generated at most once per process, loaded from / persisted to
    /// the backing store when one is attached.
    pub fn get_builtin(
        &self,
        workload: Workload,
        scale: Scale,
        seed: u64,
    ) -> Result<Arc<Trace>> {
        let key = CorpusStore::generated_key(workload.name(), scale, seed);
        self.get_or_build(&key, || {
            if let Some(store) = &self.store {
                if let Some(t) = store.get(&key)? {
                    return Ok((t, Origin::StoreLoaded));
                }
            }
            let t = workload.generate(scale, seed);
            let persisted = match &self.store {
                Some(store) => {
                    store.put(&key, &t)?;
                    true
                }
                None => false,
            };
            Ok((t, Origin::Built { persisted }))
        })
    }

    /// The shared trace of any [`TraceSource`], keyed by the source's
    /// cache key (which folds in scale/seed only for parameterized
    /// sources — a corpus- or file-backed trace is one copy total).
    /// Cached in memory only; see the module docs.
    pub fn get_source(
        &self,
        src: &dyn TraceSource,
        scale: Scale,
        seed: u64,
    ) -> Result<Arc<Trace>> {
        let key = src.cache_key(scale, seed);
        self.get_or_build(&key, || {
            Ok((src.load(scale, seed)?, Origin::Built { persisted: false }))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_share_one_arc() {
        let cache = TraceCache::new();
        let a = cache.get_builtin(Workload::Hotspot, Scale::default(), 42).unwrap();
        let b = cache.get_builtin(Workload::Hotspot, Scale::default(), 42).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let s = cache.stats();
        assert_eq!((s.builds, s.hits, s.lookups), (1, 1, 2));
        assert!(s.consistent(), "{s:?}");
        // a different seed is a different trace
        let c = cache.get_builtin(Workload::Hotspot, Scale::default(), 7).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        let s = cache.stats();
        assert_eq!(s.builds, 2);
        assert_eq!(s.lookups, 3);
        assert!(s.consistent(), "{s:?}");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn store_backed_cache_persists_and_reloads() {
        let dir = std::env::temp_dir().join(format!(
            "uvmio-cache-test-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let cache =
                TraceCache::with_store(CorpusStore::open(&dir).unwrap());
            cache.get_builtin(Workload::Bicg, Scale::default(), 42).unwrap();
            let s = cache.stats();
            assert_eq!((s.builds, s.store_writes, s.store_loads), (1, 1, 0));
        }
        {
            // a fresh process-equivalent: the miss is served from disk
            let cache =
                TraceCache::with_store(CorpusStore::open(&dir).unwrap());
            let t = cache.get_builtin(Workload::Bicg, Scale::default(), 42).unwrap();
            assert_eq!(t.name, "BICG");
            let s = cache.stats();
            assert_eq!((s.builds, s.store_loads), (0, 1));
            assert!(s.consistent(), "{s:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A second cache instance sharing the same store in the same
    /// process (a concurrent builder elsewhere wrote the entry): the
    /// lookup must settle as a store LOAD, not vanish or masquerade as a
    /// build — exactly what the invariant pins down.
    #[test]
    fn store_entry_from_concurrent_builder_counts_as_load() {
        let dir = std::env::temp_dir().join(format!(
            "uvmio-cache-conc-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let builder =
            TraceCache::with_store(CorpusStore::open(&dir).unwrap());
        let consumer =
            TraceCache::with_store(CorpusStore::open(&dir).unwrap());
        // "concurrent" builder persists the entry first
        builder.get_builtin(Workload::Atax, Scale::default(), 3).unwrap();
        // the other cache's miss is satisfied from the store
        consumer.get_builtin(Workload::Atax, Scale::default(), 3).unwrap();
        consumer.get_builtin(Workload::Atax, Scale::default(), 3).unwrap();
        let b = builder.stats();
        assert_eq!((b.lookups, b.builds, b.store_writes), (1, 1, 1), "{b:?}");
        assert!(b.consistent(), "{b:?}");
        let c = consumer.stats();
        assert_eq!(
            (c.lookups, c.hits, c.builds, c.store_loads),
            (2, 1, 0, 1),
            "{c:?}"
        );
        assert!(c.consistent(), "{c:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_gets_build_once() {
        let cache = Arc::new(TraceCache::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let cache = Arc::clone(&cache);
                s.spawn(move || {
                    cache
                        .get_builtin(Workload::Nw, Scale::default(), 42)
                        .unwrap();
                });
            }
        });
        let st = cache.stats();
        assert_eq!(st.builds, 1);
        assert_eq!(st.hits, 7);
        assert_eq!(st.lookups, 8);
        assert!(st.consistent(), "{st:?}");
    }

    #[test]
    fn failed_build_leaves_slot_retryable() {
        struct Flaky(std::sync::atomic::AtomicBool);
        impl TraceSource for Flaky {
            fn id(&self) -> String {
                "flaky".into()
            }
            fn name(&self) -> String {
                "flaky".into()
            }
            fn parameterized(&self) -> bool {
                false
            }
            fn load(&self, _s: Scale, _r: u64) -> Result<Trace> {
                if self.0.swap(false, Ordering::SeqCst) {
                    anyhow::bail!("transient");
                }
                Ok(Workload::Mvt.generate(Scale::default(), 1))
            }
        }
        let cache = TraceCache::new();
        let src = Flaky(std::sync::atomic::AtomicBool::new(true));
        assert!(cache.get_source(&src, Scale::default(), 0).is_err());
        // the failed lookup is accounted, not dropped
        let s = cache.stats();
        assert_eq!((s.lookups, s.failures, s.builds), (1, 1, 0), "{s:?}");
        assert!(s.consistent(), "{s:?}");
        // the failure did not poison the slot: the retry succeeds
        let t = cache.get_source(&src, Scale::default(), 0).unwrap();
        assert_eq!(t.name, "MVT");
        let s = cache.stats();
        assert_eq!((s.lookups, s.failures, s.builds), (2, 1, 1), "{s:?}");
        assert!(s.consistent(), "{s:?}");
    }
}
