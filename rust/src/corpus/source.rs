//! `TraceSource` — the uniform ingestion layer between "where traces
//! come from" and every consumer.
//!
//! A source is anything that can produce a [`Trace`]: a builtin
//! synthetic generator, a named corpus entry, an external CSV dump, a
//! UVM fault log, or the `+`-composition of two sources interleaved
//! into one multi-tenant trace (via [`crate::trace::multi::interleave`]).
//! The sweep runner and CLI never care which: they hold an
//! `Arc<dyn TraceSource>`, ask [`TraceCache::get_source`] for the
//! shared trace, and key the cache with [`TraceSource::cache_key`] —
//! which folds scale/seed in only for sources whose output actually
//! depends on them.
//!
//! [`parse_source`] is the CLI grammar:
//!
//! ```text
//! NW                  builtin generator (any Workload name)
//! llm:decode          LLM serving family alias (llm-weights/kv/decode)
//! corpus:mytrace      corpus entry by trace name (needs a store)
//! mytrace             same, when the name is not a builtin workload
//! csv:path/to.csv     CSV access dump, loaded directly from the file
//! uvmlog:fault.log    UVM fault log, loaded directly from the file
//! NW+corpus:mytrace   two sources interleaved as concurrent tenants
//! ```
//!
//! [`parse_tenants`] (the `sched:` grammar) additionally accepts a
//! `*N` tenant-count multiplier per segment — `sched:llm-decode*64`
//! instantiates 64 tenants of one source without a 64-term spec.
//!
//! `csv:`/`uvmlog:` consume the REST of the spec as the file path (so
//! paths may contain `+`); compose a file source as the right-hand
//! tenant of a `+` pair.
//!
//! [`TraceCache`]: super::TraceCache
//! [`TraceCache::get_source`]: super::TraceCache::get_source

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::config::Scale;
use crate::trace::workloads::Workload;
use crate::trace::{multi, Trace};

use super::import;
use super::store::CorpusStore;

/// Anything that can produce a trace. Object-safe; implementations are
/// shared across sweep workers as `Arc<dyn TraceSource>`.
pub trait TraceSource: Send + Sync {
    /// Stable identity, used for cache/store keying (`gen:NW`,
    /// `corpus:mytrace`, `csv:dump.csv`, `gen:NW+corpus:mytrace`).
    fn id(&self) -> String;

    /// Display name (what sweep records and reports show).
    fn name(&self) -> String;

    /// Does `load` output depend on (scale, seed)? File- and
    /// corpus-backed traces are fixed artifacts; generators are not.
    fn parameterized(&self) -> bool {
        true
    }

    /// Produce the trace. Called at most once per distinct cache key
    /// when loads go through [`super::TraceCache`].
    fn load(&self, scale: Scale, seed: u64) -> Result<Trace>;

    /// Cache key: the identity, plus scale/seed iff they matter.
    fn cache_key(&self, scale: Scale, seed: u64) -> String {
        if self.parameterized() {
            format!("{}:s{}:r{seed}", self.id(), scale.factor)
        } else {
            self.id()
        }
    }
}

/// A builtin synthetic generator as a source. Its cache key equals
/// [`CorpusStore::generated_key`], so composed and direct uses of the
/// same workload share one cached trace.
pub struct GeneratorSource(pub Workload);

impl TraceSource for GeneratorSource {
    fn id(&self) -> String {
        format!("gen:{}", self.0.name())
    }

    fn name(&self) -> String {
        self.0.name().to_string()
    }

    fn load(&self, scale: Scale, seed: u64) -> Result<Trace> {
        Ok(self.0.generate(scale, seed))
    }
}

/// A corpus entry addressed by trace name.
pub struct CorpusSource {
    store: CorpusStore,
    name: String,
}

impl CorpusSource {
    pub fn new(store: CorpusStore, name: &str) -> CorpusSource {
        CorpusSource { store, name: name.to_string() }
    }
}

impl TraceSource for CorpusSource {
    fn id(&self) -> String {
        format!("corpus:{}", self.name)
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn parameterized(&self) -> bool {
        false
    }

    fn load(&self, _scale: Scale, _seed: u64) -> Result<Trace> {
        self.store.find_named(&self.name)?.ok_or_else(|| {
            anyhow!(
                "no corpus entry named '{}' in {} (see `repro corpus list`)",
                self.name,
                self.store.dir().display()
            )
        })
    }
}

/// A CSV access dump loaded straight from a file (no store needed).
pub struct CsvSource {
    path: PathBuf,
    name: String,
}

impl CsvSource {
    pub fn new(path: impl Into<PathBuf>) -> CsvSource {
        let path = path.into();
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "csv-trace".to_string());
        CsvSource { path, name }
    }
}

impl TraceSource for CsvSource {
    fn id(&self) -> String {
        format!("csv:{}", self.path.display())
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn parameterized(&self) -> bool {
        false
    }

    fn load(&self, _scale: Scale, _seed: u64) -> Result<Trace> {
        import::csv_trace(&self.path, &self.name)
    }
}

/// A UVM fault log loaded straight from a file.
pub struct FaultLogSource {
    path: PathBuf,
    name: String,
}

impl FaultLogSource {
    pub fn new(path: impl Into<PathBuf>) -> FaultLogSource {
        let path = path.into();
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "uvm-log".to_string());
        FaultLogSource { path, name }
    }
}

impl TraceSource for FaultLogSource {
    fn id(&self) -> String {
        format!("uvmlog:{}", self.path.display())
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn parameterized(&self) -> bool {
        false
    }

    fn load(&self, _scale: Scale, _seed: u64) -> Result<Trace> {
        import::uvm_fault_log_trace(&self.path, &self.name)
    }
}

/// Two sources interleaved as concurrent tenants (the Table VII
/// multi-tenant methodology): tenant B gets a perturbed seed so two
/// copies of the same generator still produce distinct streams.
pub struct InterleaveSource {
    a: Arc<dyn TraceSource>,
    b: Arc<dyn TraceSource>,
}

impl InterleaveSource {
    pub fn new(a: Arc<dyn TraceSource>, b: Arc<dyn TraceSource>) -> InterleaveSource {
        InterleaveSource { a, b }
    }
}

impl TraceSource for InterleaveSource {
    fn id(&self) -> String {
        format!("{}+{}", self.a.id(), self.b.id())
    }

    fn name(&self) -> String {
        format!("{}+{}", self.a.name(), self.b.name())
    }

    fn parameterized(&self) -> bool {
        self.a.parameterized() || self.b.parameterized()
    }

    fn load(&self, scale: Scale, seed: u64) -> Result<Trace> {
        let ta = self.a.load(scale, seed)?;
        let tb = self.b.load(scale, seed ^ 1)?;
        Ok(multi::interleave(&ta, &tb))
    }
}

/// Parse a workload/source selector (see the module docs for the
/// grammar). `store` is required only to resolve corpus names.
///
/// File prefixes bind tighter than `+`: `csv:a+b.csv` is ONE file whose
/// path contains a `+`, so a file source composes only as the RIGHT
/// tenant (`NW+csv:a.csv`), and everything after its prefix is the path.
pub fn parse_source(
    spec: &str,
    store: Option<&CorpusStore>,
) -> Result<Arc<dyn TraceSource>> {
    let spec = spec.trim();
    if spec.is_empty() {
        bail!("empty workload spec");
    }
    if let Some(path) = spec.strip_prefix("csv:") {
        return Ok(Arc::new(CsvSource::new(path)));
    }
    if let Some(path) = spec.strip_prefix("uvmlog:") {
        return Ok(Arc::new(FaultLogSource::new(path)));
    }
    if let Some((a, b)) = spec.split_once('+') {
        let a = parse_source(a, store)?;
        let b = parse_source(b, store)?;
        return Ok(Arc::new(InterleaveSource::new(a, b)));
    }
    if let Some(w) = Workload::from_name(spec) {
        return Ok(Arc::new(GeneratorSource(w)));
    }
    let name = spec.strip_prefix("corpus:").unwrap_or(spec);
    match store {
        Some(s) => Ok(Arc::new(CorpusSource::new(s.clone(), name))),
        None => bail!(
            "unknown workload '{spec}': not a builtin ({}) and no corpus \
             directory to resolve it against (pass --corpus DIR, or use \
             csv:/uvmlog: prefixes for files)",
            Workload::ALL
                .iter()
                .chain(Workload::LLM.iter())
                .map(|w| w.name())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    }
}

/// Upper bound on the `*N` tenant multiplier: keeps the per-tenant
/// `tb` namespace (`u32::MAX / TB_STRIDE` ≈ 262k tenants in
/// [`crate::coordinator::MultiTenantScheduler`]) comfortably clear.
pub const MAX_TENANT_MULTIPLIER: u32 = 4096;

/// Push one `+`-free tenant segment, expanding a trailing `*N`
/// multiplier (`llm-decode*64` → 64 shared handles to one source; the
/// scheduler's per-tenant `seed ^ i` derivation makes each copy a
/// distinct stream). A suffix that does not parse as a number is not a
/// multiplier — the whole segment goes to [`parse_source`] untouched.
fn push_tenant_segment(
    out: &mut Vec<Arc<dyn TraceSource>>,
    seg: &str,
    store: Option<&CorpusStore>,
) -> Result<()> {
    if let Some((base, count)) = seg.rsplit_once('*') {
        if let Ok(n) = count.trim().parse::<u32>() {
            if n == 0 {
                bail!("tenant multiplier in '{seg}' must be at least 1");
            }
            if n > MAX_TENANT_MULTIPLIER {
                bail!(
                    "tenant multiplier in '{seg}' exceeds the maximum of \
                     {MAX_TENANT_MULTIPLIER}"
                );
            }
            let src = parse_source(base, store)?;
            for _ in 0..n {
                out.push(Arc::clone(&src));
            }
            return Ok(());
        }
    }
    out.push(parse_source(seg, store)?);
    Ok(())
}

/// Split a `+`-composed spec into its tenant sources *without*
/// interleaving them — the input grammar of scheduler-backed
/// (`sched:A+B`) sweep cells, where the merge order is decided online by
/// [`crate::coordinator::MultiTenantScheduler`] instead of offline by
/// [`crate::trace::multi::interleave`].
///
/// Same binding rules as [`parse_source`]: a `csv:`/`uvmlog:` prefix
/// consumes the rest of the spec as a file path (so file sources compose
/// only as the rightmost tenant). A spec with no `+` yields one tenant.
/// A segment may carry a `*N` tenant-count multiplier
/// (`sched:llm-decode*64`, `NW*2+Hotspot`): the segment's source is
/// repeated N times, and per-tenant seed derivation (`seed ^ i`)
/// downstream gives every copy its own stream.
pub fn parse_tenants(
    spec: &str,
    store: Option<&CorpusStore>,
) -> Result<Vec<Arc<dyn TraceSource>>> {
    let mut out: Vec<Arc<dyn TraceSource>> = Vec::new();
    let mut rest = spec.trim();
    loop {
        if rest.starts_with("csv:") || rest.starts_with("uvmlog:") {
            out.push(parse_source(rest, store)?);
            break;
        }
        match rest.split_once('+') {
            Some((head, tail)) => {
                push_tenant_segment(&mut out, head, store)?;
                rest = tail;
            }
            None => {
                push_tenant_segment(&mut out, rest, store)?;
                break;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_source_matches_store_key() {
        let src = GeneratorSource(Workload::Atax);
        assert_eq!(
            src.cache_key(Scale::default(), 42),
            CorpusStore::generated_key("ATAX", Scale::default(), 42)
        );
        let t = src.load(Scale::default(), 42).unwrap();
        assert_eq!(t, Workload::Atax.generate(Scale::default(), 42));
    }

    #[test]
    fn parse_grammar() {
        let g = parse_source("nw", None).unwrap();
        assert_eq!(g.name(), "NW");
        assert!(g.parameterized());

        let pair = parse_source("NW+Hotspot", None).unwrap();
        assert_eq!(pair.name(), "NW+Hotspot");
        assert_eq!(pair.id(), "gen:NW+gen:Hotspot");
        let t = pair.load(Scale::default(), 42).unwrap();
        t.validate().unwrap();

        let csv = parse_source("csv:/tmp/foo.csv", None).unwrap();
        assert_eq!(csv.name(), "foo");
        assert!(!csv.parameterized());
        assert_eq!(csv.cache_key(Scale::default(), 1), csv.id());

        // a + inside a file path is part of the path, not a composition…
        let plus = parse_source("csv:/tmp/batch+1.csv", None).unwrap();
        assert_eq!(plus.id(), "csv:/tmp/batch+1.csv");
        // …while a file source still composes as the right-hand tenant
        let mixed = parse_source("NW+csv:/tmp/foo.csv", None).unwrap();
        assert_eq!(mixed.name(), "NW+foo");

        assert!(parse_source("", None).is_err());
        let err = parse_source("mystery", None).unwrap_err().to_string();
        assert!(err.contains("mystery"), "{err}");
        assert!(err.contains("--corpus"), "{err}");
    }

    #[test]
    fn parse_tenants_splits_without_interleaving() {
        let ts = parse_tenants("NW+Hotspot+ATAX", None).unwrap();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[0].name(), "NW");
        assert_eq!(ts[1].name(), "Hotspot");
        assert_eq!(ts[2].name(), "ATAX");

        // a file source consumes the rest of the spec (path may hold +)
        let ts = parse_tenants("NW+csv:/tmp/a+b.csv", None).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[1].id(), "csv:/tmp/a+b.csv");

        // no '+': a single tenant
        let ts = parse_tenants("Hotspot", None).unwrap();
        assert_eq!(ts.len(), 1);

        assert!(parse_tenants("", None).is_err());
        assert!(parse_tenants("NW+", None).is_err());
    }

    #[test]
    fn tenant_multiplier_expands_segments() {
        let ts = parse_tenants("llm-decode*3", None).unwrap();
        assert_eq!(ts.len(), 3);
        assert!(ts.iter().all(|t| t.name() == "llm-decode"));
        // the copies share one source object
        assert!(Arc::ptr_eq(&ts[0], &ts[1]));

        // multipliers compose with + segments on either side
        let ts = parse_tenants("NW*2+Hotspot+llm:kv*2", None).unwrap();
        let names: Vec<String> = ts.iter().map(|t| t.name()).collect();
        assert_eq!(names, ["NW", "NW", "Hotspot", "llm-kv", "llm-kv"]);

        // *1 is the degenerate single tenant
        assert_eq!(parse_tenants("ATAX*1", None).unwrap().len(), 1);

        // zero and oversized multipliers are rejected
        assert!(parse_tenants("NW*0", None).is_err());
        assert!(parse_tenants("NW*5000", None).is_err());
        // a non-numeric suffix is not a multiplier: falls through to
        // normal source resolution (and errors as an unknown workload)
        let err = parse_tenants("NW*lots", None).unwrap_err().to_string();
        assert!(err.contains("NW*lots"), "{err}");
    }

    #[test]
    fn corpus_source_resolves_by_name() {
        let dir = std::env::temp_dir().join(format!(
            "uvmio-source-test-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = CorpusStore::open(&dir).unwrap();
        let t = Workload::TwoDConv.generate(Scale::default(), 5);
        store.import(&t).unwrap();
        // explicit corpus: prefix forces store resolution even for a
        // name that would otherwise hit the builtin generator
        let src = parse_source("corpus:2DCONV", Some(&store)).unwrap();
        assert_eq!(src.id(), "corpus:2DCONV");
        let loaded = src.load(Scale::default(), 0).unwrap();
        assert_eq!(loaded, t);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
