//! `KeyedDir` — the keyed-flat-directory machinery shared by every
//! content-addressed store in the crate.
//!
//! Both [`super::store::CorpusStore`] (`.uvmt` traces) and
//! [`crate::results::ResultStore`] (`.cell` sweep results) are the same
//! shape on disk: a flat directory of files named by the FNV-1a 64 hash
//! of their key, written atomically (private temp file in the same
//! directory, then `rename` into place) so a killed writer never
//! publishes a torn entry. This module owns that shape once — path
//! derivation, atomic writes, entry listing, and the gc sweep that
//! reaps orphaned temp files and invalid entries — so both stores gc
//! consistently and a third store costs only a codec.
//!
//! What a *valid* entry looks like is the caller's business: `gc` takes
//! a `healthy` predicate (decode the `.uvmt` header; parse the result
//! JSON and check its code version) and removes entries that fail it.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::hash::fnv1a64;

/// Monotone counter making temp-file names unique across threads.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Temp files younger than this are presumed to belong to a live
/// writer and are skipped by [`KeyedDir::gc_with_grace`]. A put writes
/// and renames in well under a second; a temp file this old is an
/// orphan.
pub const GC_TMP_GRACE: Duration = Duration::from_secs(60);

/// What a gc pass did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GcReport {
    /// invalid entries and orphaned temp files removed
    pub removed_files: usize,
    pub reclaimed_bytes: u64,
    /// healthy entries left in place
    pub kept: usize,
}

/// A flat directory of `{fnv1a64(key):016x}.{ext}` files with atomic
/// writes. Cheap to clone (it is just the path); all state is on disk.
#[derive(Debug, Clone)]
pub struct KeyedDir {
    dir: PathBuf,
    ext: &'static str,
}

impl KeyedDir {
    /// Open (creating if needed) a keyed directory of `.{ext}` entries.
    pub fn open(dir: impl Into<PathBuf>, ext: &'static str) -> Result<KeyedDir> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating store dir {}", dir.display()))?;
        Ok(KeyedDir { dir, ext })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// On-disk path an entry with this key lives at.
    pub fn path_for(&self, key: &str) -> PathBuf {
        self.dir
            .join(format!("{:016x}.{}", fnv1a64(key.as_bytes()), self.ext))
    }

    /// Atomically publish `bytes` under `key`; returns the final path.
    /// Overwrites an existing entry with the same key (idempotent puts).
    pub fn write_atomic(&self, key: &str, bytes: &[u8]) -> Result<PathBuf> {
        let path = self.path_for(key);
        let tmp = self.dir.join(format!(
            ".tmp-{}-{}.{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed),
            self.ext
        ));
        fs::write(&tmp, bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        // rename within one directory is atomic: readers see either the
        // old complete file or the new complete file, never a torn one
        fs::rename(&tmp, &path).with_context(|| {
            let _ = fs::remove_file(&tmp);
            format!("publishing {}", path.display())
        })?;
        Ok(path)
    }

    /// Read the entry stored under `key`; `Ok(None)` if absent.
    pub fn read(&self, key: &str) -> Result<Option<Vec<u8>>> {
        let path = self.path_for(key);
        match fs::read(&path) {
            Ok(b) => Ok(Some(b)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => {
                Err(e).with_context(|| format!("reading {}", path.display()))
            }
        }
    }

    /// Paths of every non-temp `.{ext}` file, sorted for determinism.
    pub fn entry_paths(&self) -> Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        let rd = fs::read_dir(&self.dir)
            .with_context(|| format!("listing {}", self.dir.display()))?;
        for entry in rd {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some(self.ext) {
                continue;
            }
            if path
                .file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with(".tmp-"))
            {
                continue;
            }
            out.push(path);
        }
        out.sort();
        Ok(out)
    }

    /// Remove orphaned temp files and entries failing the `healthy`
    /// predicate; keep everything else. Safe to run concurrently with
    /// readers (removal is per-file; a reader either got the file
    /// before or sees NotFound) and with writers: a temp file younger
    /// than `grace` is assumed to belong to a live writer and left
    /// alone.
    pub fn gc_with_grace(
        &self,
        grace: Duration,
        healthy: &mut dyn FnMut(&Path) -> bool,
    ) -> Result<GcReport> {
        let mut report = GcReport::default();
        // orphaned temp files from killed writers
        let rd = fs::read_dir(&self.dir)
            .with_context(|| format!("listing {}", self.dir.display()))?;
        for entry in rd {
            let entry = entry?;
            let path = entry.path();
            let is_tmp = path
                .file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with(".tmp-"));
            if is_tmp {
                let meta = entry.metadata().ok();
                let age = meta
                    .as_ref()
                    .and_then(|m| m.modified().ok())
                    .and_then(|t| t.elapsed().ok());
                // a fresh temp file is a live writer mid-put, not an
                // orphan — only unknown or stale mtimes are fair game
                if matches!(age, Some(a) if a < grace) {
                    continue;
                }
                let bytes = meta.map(|m| m.len()).unwrap_or(0);
                fs::remove_file(&path)
                    .with_context(|| format!("removing {}", path.display()))?;
                report.removed_files += 1;
                report.reclaimed_bytes += bytes;
            }
        }
        // entries the caller's codec rejects
        for path in self.entry_paths()? {
            if healthy(&path) {
                report.kept += 1;
            } else {
                let bytes =
                    fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
                fs::remove_file(&path)
                    .with_context(|| format!("removing {}", path.display()))?;
                report.removed_files += 1;
                report.reclaimed_bytes += bytes;
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> KeyedDir {
        let dir = std::env::temp_dir().join(format!(
            "uvmio-keydir-test-{}-{tag}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        KeyedDir::open(dir, "blob").unwrap()
    }

    #[test]
    fn atomic_write_read_and_listing() {
        let kd = tmp_dir("rw");
        assert!(kd.read("k1").unwrap().is_none());
        let p1 = kd.write_atomic("k1", b"one").unwrap();
        let p2 = kd.write_atomic("k1", b"one again").unwrap(); // idempotent path
        assert_eq!(p1, p2);
        kd.write_atomic("k2", b"two").unwrap();
        assert_eq!(kd.read("k1").unwrap().unwrap(), b"one again");
        assert_eq!(kd.entry_paths().unwrap().len(), 2);
        // temp residue and foreign extensions never show up as entries
        fs::write(kd.dir().join(".tmp-1-1.blob"), b"torn").unwrap();
        fs::write(kd.dir().join("notes.txt"), b"other").unwrap();
        assert_eq!(kd.entry_paths().unwrap().len(), 2);
        let _ = fs::remove_dir_all(kd.dir());
    }

    #[test]
    fn gc_reaps_temps_and_unhealthy_entries() {
        let kd = tmp_dir("gc");
        kd.write_atomic("good", b"healthy").unwrap();
        kd.write_atomic("bad", b"corrupt").unwrap();
        fs::write(kd.dir().join(".tmp-9-9.blob"), b"orphan").unwrap();
        // the default grace protects the fresh temp file…
        let rep = kd
            .gc_with_grace(GC_TMP_GRACE, &mut |p| {
                fs::read(p).map(|b| b == b"healthy").unwrap_or(false)
            })
            .unwrap();
        assert_eq!(rep.removed_files, 1); // the corrupt entry only
        assert_eq!(rep.kept, 1);
        // …zero grace collects it too
        let rep = kd
            .gc_with_grace(Duration::ZERO, &mut |_| true)
            .unwrap();
        assert_eq!(rep.removed_files, 1);
        assert_eq!(rep.kept, 1);
        assert!(rep.reclaimed_bytes > 0);
        assert_eq!(kd.read("good").unwrap().unwrap(), b"healthy");
        let _ = fs::remove_dir_all(kd.dir());
    }
}
