//! `CorpusStore` — the content-addressed on-disk half of the corpus.
//!
//! A store is a flat directory of `.uvmt` files named by the FNV-1a 64
//! hash of their *key*: `gen:<workload>:s<scale>:r<seed>` for
//! generator-built traces (same identity → same file, so rebuilding is
//! idempotent) and `import:<content-hash>:<name>` for ingested external
//! traces (same bytes → same file, so re-importing is idempotent too).
//! The key is also stored *inside* the file, which makes every entry
//! self-describing: `list` recovers provenance without an index file,
//! and `get` detects hash collisions by comparing the stored key.
//!
//! Writes are atomic — encode to a private temp file in the same
//! directory, then `rename` into place — so a killed `repro corpus
//! build` or a crashed sweep never publishes a torn `.uvmt`. `gc`
//! sweeps up the two failure residues that can still accumulate:
//! orphaned temp files and corrupt/unreadable `.uvmt` entries.
//!
//! The directory layout, atomic-write, and gc mechanics live in
//! [`super::keydir::KeyedDir`], shared with
//! [`crate::results::ResultStore`]; this module owns only the `.uvmt`
//! codec and the corpus key schemes.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::Scale;
use crate::trace::Trace;
use crate::util::hash::fnv1a64;

use super::format::{self, UvmtMeta};
use super::keydir::KeyedDir;

pub use super::keydir::{GcReport, GC_TMP_GRACE};

/// One `.uvmt` entry as `list`/`gc` see it: the file, its size, and
/// either its metadata or the reason it failed to parse.
#[derive(Debug, Clone)]
pub struct CorpusEntry {
    pub path: PathBuf,
    pub bytes: u64,
    /// `Ok(meta)` for healthy entries, `Err(why)` for corrupt ones.
    pub meta: std::result::Result<UvmtMeta, String>,
}

/// A content-addressed directory of `.uvmt` traces. Cheap to clone
/// (it is just the directory path); all state lives on disk.
#[derive(Debug, Clone)]
pub struct CorpusStore {
    kd: KeyedDir,
}

impl CorpusStore {
    /// Open (creating if needed) a corpus directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<CorpusStore> {
        Ok(CorpusStore { kd: KeyedDir::open(dir, "uvmt")? })
    }

    pub fn dir(&self) -> &Path {
        self.kd.dir()
    }

    /// Store key of a generator-built trace: workload × scale × seed.
    pub fn generated_key(workload: &str, scale: Scale, seed: u64) -> String {
        format!("gen:{workload}:s{}:r{seed}", scale.factor)
    }

    /// Store key of an imported trace: hash of its canonical encoding.
    pub fn import_key(trace: &Trace) -> String {
        let content = format::encode(trace, "");
        format!("import:{:016x}:{}", fnv1a64(&content), trace.name)
    }

    /// On-disk path an entry with this key lives at.
    pub fn path_for(&self, key: &str) -> PathBuf {
        self.kd.path_for(key)
    }

    /// Is an entry with this key present (no integrity check)?
    pub fn contains(&self, key: &str) -> bool {
        self.path_for(key).exists()
    }

    /// Atomically write `trace` under `key`; returns the final path.
    /// Overwrites an existing entry with the same key (idempotent puts).
    pub fn put(&self, key: &str, trace: &Trace) -> Result<PathBuf> {
        let bytes = format::encode(trace, key);
        self.kd.write_atomic(key, &bytes)
    }

    /// Load the entry stored under `key`, verifying checksum and key.
    pub fn get(&self, key: &str) -> Result<Option<Trace>> {
        let path = self.path_for(key);
        let Some(bytes) = self.kd.read(key)? else {
            return Ok(None);
        };
        let (trace, stored_key) = format::decode(&bytes)
            .with_context(|| format!("decoding {}", path.display()))?;
        if stored_key != key {
            bail!(
                "corpus key collision at {}: wanted '{key}', file holds '{stored_key}'",
                path.display()
            );
        }
        Ok(Some(trace))
    }

    /// Import an external trace under its content hash. Returns
    /// `(key, path)`.
    pub fn import(&self, trace: &Trace) -> Result<(String, PathBuf)> {
        let key = CorpusStore::import_key(trace);
        let path = self.put(&key, trace)?;
        Ok((key, path))
    }

    /// Find the unique entry whose *trace name* is `name` (how imported
    /// traces are addressed from `repro sweep --workloads <name>`).
    /// Name lookup is header-only ([`CorpusStore::find_named_path`]);
    /// only the match is decoded.
    pub fn find_named(&self, name: &str) -> Result<Option<Trace>> {
        let Some(path) = self.find_named_path(name)? else {
            return Ok(None);
        };
        let bytes = fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let (trace, _key) = format::decode(&bytes)
            .with_context(|| format!("decoding {}", path.display()))?;
        Ok(Some(trace))
    }

    /// The on-disk path of the unique entry whose trace name is `name`.
    /// Candidates are probed with a header-only streaming parse — O(1)
    /// memory per entry regardless of entry size, so name lookup never
    /// loads an access stream (the larger-than-RAM export path depends
    /// on this). Entries whose header fails to parse are skipped (they
    /// are `gc`'s business); body corruption surfaces when the chosen
    /// entry is actually read.
    pub fn find_named_path(&self, name: &str) -> Result<Option<PathBuf>> {
        let mut found: Option<PathBuf> = None;
        for path in self.entry_paths()? {
            match format::TraceReader::open(&path) {
                Ok(r) if r.meta().name == name => {
                    if let Some(prev) = &found {
                        bail!(
                            "corpus has multiple entries named '{name}' ({} and {}); \
                             address one by key or gc the stale one",
                            prev.display(),
                            path.display()
                        );
                    }
                    found = Some(path);
                }
                // different name, corrupt header (gc's job), or raced
                // with gc / concurrent rewrite
                _ => {}
            }
        }
        Ok(found)
    }

    /// A streaming reader over the entry stored under `key` (verifying
    /// the stored key matches, as [`CorpusStore::get`] does). The access
    /// stream is decoded lazily — see [`format::TraceReader`].
    pub fn reader(
        &self,
        key: &str,
    ) -> Result<Option<format::TraceReader<std::io::BufReader<fs::File>>>> {
        let path = self.path_for(key);
        if !path.exists() {
            return Ok(None);
        }
        let reader = format::TraceReader::open(&path)?;
        if reader.meta().key != key {
            bail!(
                "corpus key collision at {}: wanted '{key}', file holds '{}'",
                path.display(),
                reader.meta().key
            );
        }
        Ok(Some(reader))
    }

    /// Paths of every non-temp `.uvmt` file, sorted for determinism.
    fn entry_paths(&self) -> Result<Vec<PathBuf>> {
        self.kd.entry_paths()
    }

    /// Every `.uvmt` entry (healthy or corrupt), sorted by file name
    /// for deterministic listings.
    pub fn entries(&self) -> Result<Vec<CorpusEntry>> {
        let mut out = Vec::new();
        for path in self.entry_paths()? {
            let (bytes, meta) = match fs::read(&path) {
                Ok(b) => (
                    b.len() as u64,
                    format::stat(&b).map_err(|e| format!("{e:#}")),
                ),
                Err(e) => (0, Err(format!("unreadable: {e}"))),
            };
            out.push(CorpusEntry { path, bytes, meta });
        }
        Ok(out)
    }

    /// Metadata for one key without decoding the access stream.
    pub fn stat(&self, key: &str) -> Result<Option<UvmtMeta>> {
        let path = self.path_for(key);
        let Some(bytes) = self.kd.read(key)? else {
            return Ok(None);
        };
        Ok(Some(format::stat(&bytes).with_context(|| {
            format!("stat {}", path.display())
        })?))
    }

    /// Remove corrupt entries and orphaned temp files; keep everything
    /// healthy. Safe to run concurrently with readers (removal is
    /// per-file; a reader either got the file before or sees NotFound)
    /// and with writers: a temp file younger than [`GC_TMP_GRACE`] is
    /// assumed to belong to a live writer and left alone.
    pub fn gc(&self) -> Result<GcReport> {
        self.gc_with_grace(GC_TMP_GRACE)
    }

    /// [`CorpusStore::gc`] with an explicit temp-file grace period
    /// (tests use zero to collect temp files immediately). The sweep
    /// itself is [`KeyedDir::gc_with_grace`]; an entry is healthy when
    /// its `.uvmt` header parses.
    pub fn gc_with_grace(&self, grace: std::time::Duration) -> Result<GcReport> {
        self.kd.gc_with_grace(grace, &mut |path| {
            fs::read(path)
                .ok()
                .is_some_and(|b| format::stat(&b).is_ok())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::workloads::Workload;

    fn tmp_store(tag: &str) -> CorpusStore {
        let dir = std::env::temp_dir().join(format!(
            "uvmio-store-test-{}-{tag}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        CorpusStore::open(dir).unwrap()
    }

    #[test]
    fn put_get_roundtrip_and_idempotence() {
        let store = tmp_store("putget");
        let t = Workload::Bicg.generate(Scale::default(), 42);
        let key = CorpusStore::generated_key(&t.name, Scale::default(), 42);
        assert!(!store.contains(&key));
        assert!(store.get(&key).unwrap().is_none());
        let p1 = store.put(&key, &t).unwrap();
        let p2 = store.put(&key, &t).unwrap(); // idempotent overwrite
        assert_eq!(p1, p2);
        let back = store.get(&key).unwrap().unwrap();
        assert_eq!(back, t);
        let meta = store.stat(&key).unwrap().unwrap();
        assert_eq!(meta.key, key);
        assert_eq!(meta.accesses, t.accesses.len() as u64);
        let _ = fs::remove_dir_all(store.dir());
    }

    #[test]
    fn import_is_content_addressed() {
        let store = tmp_store("import");
        let t = Workload::Mvt.generate(Scale::default(), 1);
        let (k1, p1) = store.import(&t).unwrap();
        let (k2, p2) = store.import(&t).unwrap();
        assert_eq!(k1, k2);
        assert_eq!(p1, p2);
        assert!(k1.starts_with("import:"));
        let found = store.find_named(&t.name).unwrap().unwrap();
        assert_eq!(found, t);
        assert!(store.find_named("no-such-trace").unwrap().is_none());
        let _ = fs::remove_dir_all(store.dir());
    }

    #[test]
    fn streaming_reader_and_named_path() {
        let store = tmp_store("reader");
        let t = Workload::Nw.generate(Scale::default(), 9);
        let key = CorpusStore::generated_key(&t.name, Scale::default(), 9);
        store.put(&key, &t).unwrap();

        // streaming by key: meta first, then the exact access stream
        let mut r = store.reader(&key).unwrap().unwrap();
        assert_eq!(r.meta().key, key);
        assert_eq!(r.meta().accesses, t.accesses.len() as u64);
        let mut n = 0usize;
        while let Some(a) = r.next_access().unwrap() {
            assert_eq!(a, t.accesses[n]);
            n += 1;
        }
        assert_eq!(n, t.accesses.len());
        assert!(store.reader("gen:GHOST:s1:r0").unwrap().is_none());

        // path lookup by trace name matches the key-derived path
        let path = store.find_named_path(&t.name).unwrap().unwrap();
        assert_eq!(path, store.path_for(&key));
        assert!(store.find_named_path("ghost").unwrap().is_none());
        let _ = fs::remove_dir_all(store.dir());
    }

    #[test]
    fn gc_removes_corrupt_and_temp_files() {
        let store = tmp_store("gc");
        let t = Workload::Pathfinder.generate(Scale::default(), 3);
        let key = CorpusStore::generated_key(&t.name, Scale::default(), 3);
        store.put(&key, &t).unwrap();
        // a torn write residue and a corrupt entry
        fs::write(store.dir().join(".tmp-999-0.uvmt"), b"partial").unwrap();
        fs::write(store.dir().join("deadbeefdeadbeef.uvmt"), b"garbage").unwrap();
        assert_eq!(store.entries().unwrap().len(), 2); // temp excluded
        // the default grace period protects the fresh temp file…
        let rep = store.gc().unwrap();
        assert_eq!(rep.removed_files, 1); // corrupt entry only
        // …zero grace collects it too
        let rep = store.gc_with_grace(std::time::Duration::ZERO).unwrap();
        assert_eq!(rep.removed_files, 1);
        assert_eq!(rep.kept, 1);
        assert!(rep.reclaimed_bytes > 0);
        // healthy entry survived
        assert_eq!(store.get(&key).unwrap().unwrap(), t);
        let _ = fs::remove_dir_all(store.dir());
    }
}
