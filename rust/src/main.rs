//! `repro` — launcher for the uvmio reproduction.
//!
//! ```text
//! repro exp <table1|table2|...|fig14|all> [--quick] [--scale N] [--seed N]
//! repro simulate --workload NW --strategy baseline --oversub 125
//! repro accuracy --workload Hotspot --method ours
//! repro info
//! ```
//!
//! Experiments write `reports/<id>.csv` next to the console table.

use std::process::ExitCode;
use std::rc::Rc;

use uvmio::config::Scale;
use uvmio::coordinator::{
    offline_accuracy, online_accuracy, run_intelligent, run_rule_based,
    RunSpec, Strategy, TrainOpts,
};
use uvmio::exp::{self, ExpContext, ExpOpts};
use uvmio::predictor::features::samples_from_trace;
use uvmio::predictor::IntelligentConfig;
use uvmio::runtime::{Manifest, Runtime};
use uvmio::trace::workloads::Workload;
use uvmio::util::cli::Args;

const USAGE: &str = "\
repro — intelligent UVM oversubscription management (paper reproduction)

USAGE:
  repro exp <id|all> [--quick] [--scale N] [--seed N] [--reports DIR]
      regenerate a paper table/figure (table1 table2 table3 table4 table6
      table7 fig3 fig4 fig5 fig6 fig10 fig11 fig12 fig13 fig14)
  repro simulate --workload W --strategy S [--oversub PCT] [--scale N] [--seed N]
      one simulation cell; strategies: baseline demand-hpe tree-hpe
      demand-belady demand-lru demand-random uvmsmart intelligent
  repro accuracy --workload W [--method online|offline|ours] [--seed N]
      predictor accuracy on one workload
  repro info
      artifact manifest + workload inventory
";

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn real_main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    match args.subcommand.as_deref() {
        Some("exp") => cmd_exp(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("accuracy") => cmd_accuracy(&args),
        Some("info") => cmd_info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn opts_from(args: &Args) -> anyhow::Result<ExpOpts> {
    let mut opts = ExpOpts::default();
    opts.scale = Scale {
        factor: args.get_parse("scale", 1u32).map_err(anyhow::Error::msg)?,
    };
    opts.seed = args.get_parse("seed", 42u64).map_err(anyhow::Error::msg)?;
    opts.quick = args.has("quick");
    if let Some(dir) = args.get("reports") {
        opts.reports_dir = dir.into();
    }
    if let Some(dir) = args.get("artifacts") {
        opts.artifacts_dir = dir.into();
    }
    Ok(opts)
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["quick", "scale", "seed", "reports", "artifacts"])
        .map_err(anyhow::Error::msg)?;
    let id = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let mut ctx = ExpContext::new(opts_from(args)?);
    exp::run(&id, &mut ctx)
}

fn parse_strategy(s: &str) -> anyhow::Result<Strategy> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "baseline" => Strategy::Baseline,
        "demand-hpe" => Strategy::DemandHpe,
        "tree-hpe" => Strategy::TreeHpe,
        "demand-belady" => Strategy::DemandBelady,
        "demand-lru" => Strategy::DemandLru,
        "demand-random" => Strategy::DemandRandom,
        "uvmsmart" => Strategy::UvmSmart,
        "intelligent" => Strategy::Intelligent,
        other => anyhow::bail!("unknown strategy {other}"),
    })
}

fn parse_workload(args: &Args) -> anyhow::Result<Workload> {
    let name = args
        .get("workload")
        .ok_or_else(|| anyhow::anyhow!("--workload required"))?;
    Workload::from_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown workload {name}"))
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["workload", "strategy", "oversub", "scale", "seed", "artifacts"])
        .map_err(anyhow::Error::msg)?;
    let opts = opts_from(args)?;
    let w = parse_workload(args)?;
    let strategy = parse_strategy(args.get_or("strategy", "baseline"))?;
    let oversub = args.get_parse("oversub", 125u32).map_err(anyhow::Error::msg)?;
    let trace = w.generate(opts.scale, opts.seed);
    let spec = RunSpec::new(&trace, oversub);

    let cell = if strategy == Strategy::Intelligent {
        let runtime = Runtime::new(&opts.artifacts_dir)?;
        let model = Rc::new(runtime.model("predictor")?);
        run_intelligent(&spec, &model, &runtime, IntelligentConfig::default())?
    } else {
        run_rule_based(&spec, strategy)
    };
    let s = &cell.outcome.stats;
    println!("workload        : {} ({} pages, {} accesses)", trace.name,
             trace.working_set_pages, trace.accesses.len());
    println!("strategy        : {}", strategy.name());
    println!("oversubscription: {oversub}% (capacity {} pages)", spec.cfg.capacity_pages);
    println!("faults          : {}", s.faults);
    println!("migrations      : {}", s.migrations);
    println!("evictions       : {}", s.evictions);
    println!("prefetches      : {} (garbage {})", s.prefetches, s.garbage_prefetches);
    println!("zero-copy       : {}", s.zero_copy);
    println!("pages thrashed  : {} events / {} unique", s.thrash_events,
             s.thrashed_pages.len());
    println!("IPC             : {:.4}", s.ipc());
    if cell.inference_calls > 0 {
        println!("inference calls : {} ({} predictions, {} patterns)",
                 cell.inference_calls, cell.model_predictions, cell.patterns_used);
    }
    if cell.outcome.crashed {
        println!("status          : CRASHED (runaway thrashing)");
    }
    Ok(())
}

fn cmd_accuracy(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["workload", "method", "scale", "seed", "artifacts"])
        .map_err(anyhow::Error::msg)?;
    let opts = opts_from(args)?;
    let w = parse_workload(args)?;
    let method = args.get_or("method", "online").to_string();
    let runtime = Runtime::new(&opts.artifacts_dir)?;
    let model = Rc::new(runtime.model("predictor")?);
    let dims = uvmio::coordinator::feat_dims(&runtime);
    let trace = w.generate(opts.scale, opts.seed);
    let (samples, vocab) = samples_from_trace(&trace, dims);
    println!("workload: {} ({} samples, {} delta classes)",
             trace.name, samples.len(), vocab.assigned());
    let report = match method.as_str() {
        "online" => online_accuracy(&model, &dims, &samples, &TrainOpts::default(), None)?,
        "ours" => online_accuracy(&model, &dims, &samples, &TrainOpts::ours(), None)?,
        "offline" => offline_accuracy(&model, &dims, &samples, &TrainOpts::default())?,
        other => anyhow::bail!("unknown method {other}"),
    };
    println!("method  : {}", report.method);
    println!("top-1   : {:.3} over {} evaluations", report.top1, report.evaluated);
    println!("training: {} steps, {} model(s)", report.train_steps, report.patterns_used);
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    println!("workloads:");
    for w in Workload::ALL {
        let t = w.generate(Scale::default(), 42);
        println!(
            "  {:12} {:>6} pages  {:>7} accesses  {} kernels  [{}]",
            w.name(),
            t.working_set_pages,
            t.accesses.len(),
            t.kernels,
            w.category()
        );
    }
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts ({}):", dir.display());
            for (name, e) in &m.models {
                println!(
                    "  {:10} {:>7} params  fwd/train/init present  ({:.2} MB params)",
                    name, e.param_count, e.params_mb
                );
            }
        }
        Err(_) => println!("artifacts: not built (run `make artifacts`)"),
    }
    Ok(())
}
