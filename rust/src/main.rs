//! `repro` — launcher for the uvmio reproduction.
//!
//! ```text
//! repro exp <table1|table2|...|fig14|all> [--quick] [--scale N] [--seed N]
//! repro simulate --workload NW --strategy baseline --oversub 125
//! repro simulate --stream corpus:myapp --corpus corpus --progress
//! repro sweep --workloads all --strategies baseline,uvmsmart --oversub 100,125,150
//! repro sweep --workloads sched:NW+Hotspot --schedule bandwidth-fair
//! repro sweep --workloads sched:NW+Hotspot --schedule weighted:3,1 --cost-model coherent-link
//! repro sweep --workloads llm-decode,sched:llm-kv*8 --strategies baseline,hpe-preevict
//! repro sweep --workloads all --results results --resume
//! repro exp serving --quick
//! repro corpus build --workloads all --seeds 42,7
//! repro corpus import faults.csv --name myapp
//! repro results list --results results
//! repro serve --addr 127.0.0.1:7077 --corpus corpus --results results
//! repro accuracy --workload Hotspot --method ours
//! repro lint --deny
//! repro info
//! ```
//!
//! Experiments write `reports/<id>.csv` next to the console table;
//! sweeps stream `reports/sweep.csv` + `reports/sweep.jsonl`; the trace
//! corpus lives in `corpus/` (override with `--corpus DIR`).

use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Instant;

use uvmio::api::{
    apply_prediction_overhead, parse_sweep_workloads, ConsoleSink, CsvSink,
    JsonlSink, ProgressObserver, StrategyCtx, StrategyRegistry, SweepRunner,
    SweepSink, SweepSpec,
};
use uvmio::config::{Scale, SimConfig};
use uvmio::coordinator::{
    offline_accuracy, online_accuracy, RunSpec, SchedulePolicy, TrainOpts,
};
use uvmio::corpus::{self, CorpusStore, TraceCache};
use uvmio::exp::{self, ExpContext, ExpOpts};
use uvmio::predictor::features::samples_from_trace;
use uvmio::predictor::{native_dims, NativeModel};
use uvmio::results::{serve_stdin, serve_tcp, ResultStore, ServeShared};
use uvmio::runtime::{Manifest, ModelBackend, PredictorKind, Runtime};
use uvmio::sim::{check_residency, Arena, AuditObserver, CostModelKind, Session};
use uvmio::trace::workloads::Workload;
use uvmio::trace::Trace;
use uvmio::util::cli::Args;

const USAGE: &str = "\
repro — intelligent UVM oversubscription management (paper reproduction)

USAGE:
  repro exp <id|all> [--quick] [--scale N] [--seed N] [--reports DIR]
            [--corpus DIR] [--cost-model table-v|coherent-link]
            [--predictor native|stub|pjrt] [--results DIR]
      regenerate a paper table/figure (table1 table2 table3 table4 table6
      table7 fig3 fig4 fig5 fig6 fig10 fig11 fig12 fig13 fig14), or the
      forward-looking `serving` table: LLM request mixes (chat, batch)
      swept over the policy landscape at 125/150% under BOTH cost
      models, reporting tokens serviced per megacycle and thrashed
      pages (tokens are recomputed from the mix seed, so memoized
      serving cells report throughput without loading traces). With
      --corpus DIR the experiment trace cache is backed by the .uvmt
      store: traces generated once are persisted and reloaded by later
      runs (shared with `repro sweep --corpus` and `repro corpus build`).
      --cost-model prices every simulated cell (default table-v, the
      paper's PCIe pricing). --predictor picks the model backend for
      model-backed cells, including the §V accuracy tables: the default
      `native` is the artifact-free pure-Rust predictor, so the whole
      suite runs from a clean checkout; stub/pjrt use `make artifacts`.
      --results DIR memoizes every deterministic grid cell, so
      re-running a table/figure skips already-computed simulations
      (store shared with `repro sweep --results`)
  repro simulate --workload W --strategy S [--oversub PCT] [--scale N] [--seed N]
              [--cost-model table-v|coherent-link] [--predictor B] [--audit]
      one simulation cell; S is ANY registered strategy name
      (`repro info` lists them; builtin: baseline demand-hpe tree-hpe
      tree-evict demand-belady demand-lru demand-random uvmsmart
      intelligent intelligent-native — tree-evict is the directive-API
      pre-eviction configuration: its drain traffic runs on the
      background-transfer queue and overlaps compute;
      intelligent-native is the full solution on the artifact-free
      native predictor, so it needs no `make artifacts`). --cost-model
      swaps the timing model (default table-v, the paper's PCIe
      pricing; coherent-link prices the same run like
      Grace-Hopper-class hardware). --predictor picks the model backend
      (native|stub|pjrt, default native) for artifact-backed strategies
      like `intelligent`. --audit attaches the runtime invariant
      auditor: every simulation event is checked against the counter
      conservation laws (tlb_hits+tlb_misses == accesses, eviction /
      pre-eviction / writeback orderings, capacity bounds, counter
      monotonicity) and the run panics with the offending event on the
      first violation
  repro simulate --stream corpus:NAME [--strategy S] [--oversub PCT]
              [--corpus DIR] [--progress [N]] [--cost-model M] [--audit]
      one-off streamed run: decode the named .uvmt corpus entry access
      by access through a Session in O(1) memory (entries larger than
      RAM stream fine); --progress prints a mid-run snapshot line every
      N faults (default 100000). Oracle strategies that need the whole
      trace up front (demand-belady) are rejected
  repro sweep [--workloads all|W1,W2,..] [--strategies all|S1,S2,..]
              [--oversub P1,P2,..] [--seeds N1,N2,..] [--threads N]
              [--scale N] [--reports DIR] [--artifacts DIR] [--corpus DIR]
              [--crash-at L=T,..] [--progress [N]] [--schedule POLICY]
              [--cost-model table-v|coherent-link] [--predictor B]
              [--results DIR] [--resume]
      run the (workload × strategy × oversubscription × seed) grid in
      parallel across threads (artifact-backed strategies run on a
      serialized lane); streams a console table and writes
      reports/sweep.csv + reports/sweep.jsonl in deterministic grid
      order. Defaults: all workloads, the rule-based strategies,
      oversub 125, seed 42, one thread per core. Traces are built once
      per (workload, scale, seed) via a shared cache; with --corpus DIR
      they are also persisted to / reloaded from the .uvmt store, and
      workload names may be corpus entries, csv:FILE / uvmlog:FILE
      imports, or A+B multi-tenant compositions. Besides the 11 paper
      benchmarks, the LLM serving family is addressable by name
      (llm-weights llm-kv llm-decode, or the llm:weights|kv|decode
      aliases): layer-sweep weight reads, growing-then-dying KV-cache
      regions with explicit end-of-request kernels, and the
      prefill+decode composite — the workloads where pre-evict-aware
      strategies separate from reactive ones. sched:A+B cells run
      their tenants through the ONLINE MultiTenantScheduler (shared
      device memory + interconnect, per-tenant cycle/fault attribution
      in sweep.jsonl) instead of an offline pre-interleave; --schedule
      picks the policy for all sched: cells (proportional, round-robin,
      fault-aware, bandwidth-fair, weighted:W1,W2,.. for priority/QoS
      time-slicing — tenant i gets slots in proportion to Wi; default
      proportional — for two tenants byte-identical to the offline A+B
      merge). A sched: segment takes a *N tenant-count multiplier
      (sched:llm-decode*64 = 64 tenants of one source; tenant i loads
      at seed^i, so every copy is a distinct stream — large fleets
      without large CLI strings). --cost-model prices every cell (recorded as a per-cell
      column in sweep.csv/jsonl). --crash-at maps an oversubscription
      level to a crash threshold (thrash events), e.g.
      --crash-at 150=100000 reproduces the Fig-14 crash columns.
      --progress streams a mid-run snapshot line (stderr) per cell every
      N faults (default 100000), including link occupancy (total +
      background pre-eviction cycles) — live observability for long
      sweeps. --predictor picks the backend for artifact-backed
      strategies; `intelligent-native` ignores it (always native) and
      runs on the parallel lane like the rule-based strategies.
      --results DIR memoizes every artifact-free cell in a
      content-addressed store: re-running an identical sweep skips all
      of them (`skipped N cells`, byte-identical sweep.csv/jsonl), an
      interrupted sweep continues from the cells already on disk, and
      an incremental sweep costs only the new cells. --resume asserts
      that intent: it requires --results and errors if the store
      directory does not exist yet. Entries invalidate automatically on
      code-version changes (`repro results gc` reaps them)
  repro results list [--results DIR]
      list memoized sweep cells (strategy, status, key), flagging stale
      (other code version) and corrupt entries
  repro results gc [--results DIR]
      remove stale/corrupt entries and orphaned temp files
  repro serve [--addr HOST:PORT | --stdin] [--corpus DIR] [--results DIR]
              [--threads N]
      long-running sweep service: newline-delimited JSON jobs in,
      newline-delimited JSON events out (one `cell` line per finished
      cell in grid order, then `job_done` with cells/errors/skipped;
      malformed jobs get an `error` line and the server keeps going).
      Default transport is TCP on 127.0.0.1:7077, one thread per
      connection; --stdin serves a single stdin/stdout session for CI
      and piping. All jobs and connections share one warm trace cache
      (corpus-backed with --corpus) and, with --results, one memoized
      result store — a cell any client ever computed is a lookup for
      all of them. Job fields: workloads (required; the sweep selector
      grammar), id, strategies, oversub, seeds, scale, cost_model,
      schedule, crash_at ({\"150\":\"100000\"}), threads
  repro corpus build [--workloads all|W1,..] [--scale N] [--seeds N1,..]
              [--corpus DIR]
      generate builtin traces into the corpus (.uvmt, content-addressed)
  repro corpus import <file> [--name N] [--format csv|uvmlog] [--corpus DIR]
      ingest an external trace (CSV page-access dump or UVM fault log),
      validate it, and store it under its content hash; afterwards
      `repro sweep --corpus DIR --workloads N` runs it by name
  repro corpus export <name> [--csv FILE] [--key KEY] [--corpus DIR]
      stream a corpus entry back out as a CSV access dump (the exact
      inverse of `import --format csv`; decodes lazily, so entries
      larger than RAM export fine). --key addresses an entry directly
      when several share a trace name
  repro corpus list [--corpus DIR]
      list corpus entries (name, workload category — streaming/regular/
      mixed/random/llm, '-' for imports — size, provenance key), flag
      corrupt ones
  repro corpus gc [--corpus DIR]
      remove corrupt entries and orphaned temp files
  repro accuracy --workload W [--method online|offline|ours] [--seed N]
              [--predictor native|stub|pjrt]
      predictor accuracy on one workload (default backend: the
      artifact-free native predictor)
  repro lint [--deny] [--write-baseline] [PATH]
      dependency-free determinism/conservation static analysis over the
      crate tree at PATH (default: the uvmio crate). Rules:
      nondet-iteration (hash-order iteration in result-bearing modules;
      waive with `// lint: sorted <reason>` on or directly above the
      line, or sort within two lines), wall-clock (Instant/SystemTime/
      ambient entropy in library code), unwrap-ratchet (unwrap/expect
      counts may only go down vs the committed lint-baseline.txt;
      regenerate a tighter ceiling with --write-baseline),
      counter-conservation (every u64 Stats counter reaches
      MetricsSnapshot, the sweep CSV header, and the cell/v1 codec), and
      registry-exhaustiveness (registry ≡ BUILTIN test ≡ policy doc
      list). --deny exits non-zero on any violation (the blocking CI
      lane)
  repro info
      registered strategies + artifact manifest + workload inventory
";

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn real_main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    match args.subcommand.as_deref() {
        Some("exp") => cmd_exp(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("corpus") => cmd_corpus(&args),
        Some("results") => cmd_results(&args),
        Some("serve") => cmd_serve(&args),
        Some("accuracy") => cmd_accuracy(&args),
        Some("lint") => cmd_lint(&args),
        Some("info") => cmd_info(),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn opts_from(args: &Args) -> anyhow::Result<ExpOpts> {
    let mut opts = ExpOpts::default();
    opts.scale = Scale {
        factor: args.get_parse("scale", 1u32).map_err(anyhow::Error::msg)?,
    };
    opts.seed = args.get_parse("seed", 42u64).map_err(anyhow::Error::msg)?;
    opts.quick = args.has("quick");
    if let Some(dir) = args.get("reports") {
        opts.reports_dir = dir.into();
    }
    if let Some(dir) = args.get("artifacts") {
        opts.artifacts_dir = dir.into();
    }
    if let Some(dir) = args.get("corpus") {
        opts.corpus_dir = Some(dir.into());
    }
    if let Some(dir) = args.get("results") {
        opts.results_dir = Some(dir.into());
    }
    opts.cost_model = parse_cost_model(args)?;
    opts.predictor = parse_predictor(args)?;
    Ok(opts)
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&[
        "quick", "scale", "seed", "reports", "artifacts", "corpus",
        "cost-model", "predictor", "results",
    ])
    .map_err(anyhow::Error::msg)?;
    let id = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let mut ctx = ExpContext::new(opts_from(args)?)?;
    exp::run(&id, &mut ctx)?;
    let cs = ctx.cache.stats();
    if ctx.opts.corpus_dir.is_some() {
        eprintln!(
            "trace cache: {} built, {} loaded from corpus, {} persisted, {} shared hits",
            cs.builds, cs.store_loads, cs.store_writes, cs.hits
        );
    }
    if let Some(rs) = &ctx.results {
        let s = rs.stats();
        eprintln!(
            "results store: skipped {} cells (memoized), {} computed and \
             persisted ({})",
            s.hits,
            s.writes,
            rs.dir().display()
        );
    }
    Ok(())
}

fn parse_workload(args: &Args) -> anyhow::Result<Workload> {
    let name = args
        .get("workload")
        .ok_or_else(|| anyhow::anyhow!("--workload required"))?;
    Workload::from_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown workload {name}"))
}

/// `all` or a comma-separated workload list.
fn parse_workloads(selector: &str) -> anyhow::Result<Vec<Workload>> {
    if selector.trim().eq_ignore_ascii_case("all") {
        return Ok(Workload::ALL.to_vec());
    }
    let mut out = Vec::new();
    for part in selector.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(Workload::from_name(part).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown workload {part}; known: {}",
                Workload::ALL
                    .iter()
                    .chain(Workload::LLM.iter())
                    .map(|w| w.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?);
    }
    if out.is_empty() {
        anyhow::bail!("empty workload list");
    }
    Ok(out)
}

/// Comma-separated typed list; errors carry the flag name.
fn parse_list<T: std::str::FromStr>(s: &str, flag: &str) -> anyhow::Result<Vec<T>> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        out.push(
            part.parse::<T>()
                .map_err(|_| anyhow::anyhow!("--{flag}: cannot parse {part:?}"))?,
        );
    }
    if out.is_empty() {
        anyhow::bail!("--{flag}: empty list");
    }
    Ok(out)
}

/// `--cost-model table-v|coherent-link` (default: the paper's Table V).
fn parse_cost_model(args: &Args) -> anyhow::Result<CostModelKind> {
    match args.get("cost-model") {
        None => Ok(CostModelKind::default()),
        Some(s) => CostModelKind::from_name(s).ok_or_else(|| {
            anyhow::anyhow!(
                "--cost-model: unknown model {s:?}; known: {}",
                CostModelKind::ALL
                    .iter()
                    .map(|k| k.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        }),
    }
}

/// `--predictor native|stub|pjrt` (default: the artifact-free native
/// backend, so model-backed strategies run from a clean checkout).
fn parse_predictor(args: &Args) -> anyhow::Result<PredictorKind> {
    match args.get("predictor") {
        None => Ok(PredictorKind::default()),
        Some(s) => PredictorKind::from_name(s).ok_or_else(|| {
            anyhow::anyhow!(
                "--predictor: unknown backend {s:?}; known: {}",
                PredictorKind::ALL
                    .iter()
                    .map(|k| k.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        }),
    }
}

/// [`StrategyCtx`] for artifact-backed strategies under the selected
/// predictor backend: native self-constructs (no artifacts on disk);
/// stub/pjrt load the manifest runtime from `artifacts_dir`.
fn strategy_ctx_for(
    predictor: PredictorKind,
    artifacts_dir: &std::path::Path,
) -> anyhow::Result<StrategyCtx> {
    match predictor {
        PredictorKind::Native => {
            let model: Arc<dyn ModelBackend> =
                Arc::new(NativeModel::for_model("predictor")?);
            Ok(StrategyCtx::with_model(model, native_dims()))
        }
        other => {
            other.ensure_available()?;
            let runtime = Runtime::new(artifacts_dir)?;
            Ok(StrategyCtx::from_runtime(&runtime)?)
        }
    }
}

/// `--progress` alone uses the default cadence; `--progress N` overrides
/// it (N = faults between snapshot lines); absent = disabled.
fn parse_progress(args: &Args) -> anyhow::Result<u64> {
    match args.get("progress") {
        None => Ok(0),
        Some(uvmio::util::cli::FLAG_SET) => Ok(100_000),
        Some(v) => v.parse::<u64>().map_err(|_| {
            anyhow::anyhow!("--progress: cannot parse {v:?} (want a fault count)")
        }),
    }
}

/// The `simulate --stream` path: run a `.uvmt` corpus entry through a
/// streaming [`Session`] (O(1) memory — the access vector is never
/// materialized), with optional mid-run progress snapshots.
fn cmd_simulate_stream(args: &Args, stream: &str) -> anyhow::Result<()> {
    // flags of the materialized path are ignored by a streamed run —
    // reject them loudly instead of silently doing something else
    for flag in ["workload", "scale", "seed"] {
        if args.has(flag) {
            anyhow::bail!(
                "--{flag} does not apply to `repro simulate --stream` \
                 (the stream names the input; geometry comes from the \
                 .uvmt header)"
            );
        }
    }
    let opts = opts_from(args)?;
    let store = CorpusStore::open(args.get_or("corpus", "corpus"))?;
    let name = stream.strip_prefix("corpus:").unwrap_or(stream);
    let path = store.find_named_path(name)?.ok_or_else(|| {
        anyhow::anyhow!(
            "no corpus entry named '{name}' in {} (see `repro corpus list`)",
            store.dir().display()
        )
    })?;
    let mut reader = uvmio::corpus::TraceReader::open(&path)?;
    let meta = reader.meta().clone();

    let registry = StrategyRegistry::builtin();
    let entry = registry.get(args.get_or("strategy", "baseline"))?;
    if entry.needs_trace {
        anyhow::bail!(
            "strategy '{}' needs the whole trace up front (offline oracle) \
             and cannot drive a streamed session; use \
             `repro simulate --workload` instead",
            entry.name
        );
    }
    let oversub = args.get_parse("oversub", 125u32).map_err(anyhow::Error::msg)?;

    // the placeholder trace only parameterizes the policy factory —
    // geometry and capacity come from the .uvmt header
    let placeholder = Trace::from_accesses(
        &meta.name,
        meta.working_set_pages,
        meta.kernels,
        Vec::new(),
    );
    let cost_model = opts.cost_model;
    let cfg = SimConfig::default().with_oversubscription(meta.touched_pages, oversub);
    let spec = RunSpec {
        trace: &placeholder,
        oversub_percent: oversub,
        cfg,
        crash_threshold: None,
        cost_model,
    };
    let ctx = if entry.needs_artifacts {
        strategy_ctx_for(opts.predictor, &opts.artifacts_dir)?
    } else {
        StrategyCtx::default()
    };
    let policy = entry.build(&spec, &ctx)?;

    let arena = Arena::new(meta.working_set_pages, meta.allocations.clone());
    let mut session = Session::new(spec.cfg.clone(), arena, policy);
    if cost_model != CostModelKind::default() {
        session = session.with_cost_model(cost_model.build(&spec.cfg));
    }
    let progress = parse_progress(args)?;
    if progress > 0 {
        session.add_observer(Box::new(ProgressObserver::new(
            format!("{}/{}@{}%", meta.name, entry.name, oversub),
            progress,
            meta.accesses,
        )));
    }
    if args.has("audit") {
        session.add_observer(Box::new(AuditObserver::new(spec.cfg.capacity_pages)));
    }
    session.feed_results(&mut reader)?;
    if args.has("audit") {
        // end-of-stream structural check the event auditor cannot see:
        // dense-table residency bitset vs its maintained counter
        check_residency(session.memory());
    }

    // same §V-C prediction-overhead post-pass as the registry path
    let instr = session.policy().instrumentation();
    let mut outcome = session.finish();
    apply_prediction_overhead(&mut outcome, &instr, &spec.cfg);

    let s = &outcome.stats;
    println!("stream          : {} ({} pages, {} accesses, .uvmt streamed)",
             meta.name, meta.working_set_pages, meta.accesses);
    println!("strategy        : {} ({})", entry.display, entry.name);
    println!("oversubscription: {oversub}% (capacity {} pages)", spec.cfg.capacity_pages);
    println!("cost model      : {}", cost_model.name());
    println!("faults          : {}", s.faults);
    println!("migrations      : {}", s.migrations);
    println!("evictions       : {} ({} pre-evicted, {} avoided)",
             s.evictions, s.pre_evictions, s.evictions_avoided);
    println!("prefetches      : {} (garbage {})", s.prefetches, s.garbage_prefetches);
    println!("zero-copy       : {}", s.zero_copy);
    println!("pages thrashed  : {} events / {} unique", s.thrash_events,
             s.thrashed_pages.len());
    println!("IPC             : {:.4}", s.ipc());
    if instr.inference_calls > 0 {
        println!("inference calls : {} ({} predictions, {} patterns)",
                 instr.inference_calls, instr.predictions, instr.patterns_used);
    }
    if outcome.crashed {
        println!("status          : CRASHED (runaway thrashing)");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&[
        "workload", "strategy", "oversub", "scale", "seed", "artifacts",
        "stream", "corpus", "progress", "cost-model", "predictor", "audit",
    ])
    .map_err(anyhow::Error::msg)?;
    if let Some(stream) = args.get("stream") {
        let stream = stream.to_string();
        return cmd_simulate_stream(args, &stream);
    }
    // stream-only flags would be silently ignored here — reject loudly
    for flag in ["corpus", "progress"] {
        if args.has(flag) {
            anyhow::bail!(
                "--{flag} applies only to `repro simulate --stream corpus:NAME`"
            );
        }
    }
    let opts = opts_from(args)?;
    let w = parse_workload(args)?;
    let registry = StrategyRegistry::builtin();
    let spec_entry = registry.get(args.get_or("strategy", "baseline"))?;
    let strategy = spec_entry.name.clone();
    let display = spec_entry.display.clone();
    let needs_artifacts = spec_entry.needs_artifacts;
    let oversub = args.get_parse("oversub", 125u32).map_err(anyhow::Error::msg)?;
    let cost_model = opts.cost_model;
    let trace = w.generate(opts.scale, opts.seed);
    let spec = RunSpec::new(&trace, oversub).with_cost_model(cost_model);

    let ctx = if needs_artifacts {
        strategy_ctx_for(opts.predictor, &opts.artifacts_dir)?
    } else {
        StrategyCtx::default()
    };
    let cell = if args.has("audit") {
        registry.run_observed(
            &strategy,
            &spec,
            &ctx,
            vec![Box::new(AuditObserver::new(spec.cfg.capacity_pages))],
        )?
    } else {
        registry.run(&strategy, &spec, &ctx)?
    };
    let s = &cell.outcome.stats;
    println!("workload        : {} ({} pages, {} accesses)", trace.name,
             trace.working_set_pages, trace.accesses.len());
    println!("strategy        : {display} ({strategy})");
    println!("oversubscription: {oversub}% (capacity {} pages)", spec.cfg.capacity_pages);
    println!("cost model      : {}", cost_model.name());
    println!("faults          : {}", s.faults);
    println!("migrations      : {}", s.migrations);
    println!("evictions       : {} ({} pre-evicted, {} avoided)",
             s.evictions, s.pre_evictions, s.evictions_avoided);
    println!("prefetches      : {} (garbage {})", s.prefetches, s.garbage_prefetches);
    println!("zero-copy       : {}", s.zero_copy);
    println!("pages thrashed  : {} events / {} unique", s.thrash_events,
             s.thrashed_pages.len());
    println!("IPC             : {:.4}", s.ipc());
    if cell.inference_calls > 0 {
        println!("inference calls : {} ({} predictions, {} patterns)",
                 cell.inference_calls, cell.model_predictions, cell.patterns_used);
    }
    if cell.outcome.crashed {
        println!("status          : CRASHED (runaway thrashing)");
    }
    Ok(())
}

/// `--crash-at 150=100000,125=200000` → per-level thresholds.
fn parse_crash_at(s: &str) -> anyhow::Result<Vec<(u32, u64)>> {
    let mut out = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (level, t) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--crash-at: want LEVEL=THRESHOLD, got {part:?}"))?;
        out.push((
            level.trim().parse::<u32>().map_err(|_| {
                anyhow::anyhow!("--crash-at: cannot parse level {level:?}")
            })?,
            t.trim().parse::<u64>().map_err(|_| {
                anyhow::anyhow!("--crash-at: cannot parse threshold {t:?}")
            })?,
        ));
    }
    Ok(out)
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&[
        "workloads", "strategies", "oversub", "seeds", "threads", "scale",
        "reports", "artifacts", "corpus", "crash-at", "progress", "schedule",
        "cost-model", "predictor", "results", "resume",
    ])
    .map_err(anyhow::Error::msg)?;
    let registry = StrategyRegistry::builtin();
    let store = match args.get("corpus") {
        Some(dir) => Some(CorpusStore::open(dir)?),
        None => None,
    };
    let schedule = match args.get("schedule") {
        None => SchedulePolicy::default(),
        Some(s) => SchedulePolicy::from_name(s).ok_or_else(|| {
            anyhow::anyhow!(
                "--schedule: unknown policy {s:?}; known: {}, weighted:W1,W2,.. \
                 (positive integer weights)",
                SchedulePolicy::ALL
                    .iter()
                    .map(|p| p.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?,
    };
    let workloads = parse_sweep_workloads(
        args.get_or("workloads", "all"),
        store.as_ref(),
        schedule,
    )?;
    let strategies = registry.resolve_list(args.get_or(
        "strategies",
        "baseline,demand-hpe,tree-hpe,tree-evict,demand-belady,demand-lru,\
         demand-random,uvmsmart",
    ))?;
    let oversub = parse_list::<u32>(args.get_or("oversub", "125"), "oversub")?;
    let seeds = parse_list::<u64>(args.get_or("seeds", "42"), "seeds")?;
    let threads =
        args.get_parse("threads", 0usize).map_err(anyhow::Error::msg)?;
    let scale = Scale {
        factor: args.get_parse("scale", 1u32).map_err(anyhow::Error::msg)?,
    };
    let reports: PathBuf = args.get_or("reports", "reports").into();

    // model-carrying ctx only when an artifact-backed strategy is in the
    // grid (intelligent-native self-constructs per cell and stays on the
    // parallel lane, so it does NOT force one)
    let ctx = if strategies
        .iter()
        .any(|s| registry.get(s).map(|e| e.needs_artifacts).unwrap_or(false))
    {
        let artifacts = args.get_or("artifacts", "");
        let dir = if artifacts.is_empty() {
            Manifest::default_dir()
        } else {
            artifacts.into()
        };
        strategy_ctx_for(parse_predictor(args)?, &dir)?
    } else {
        StrategyCtx::default()
    };

    let mut sweep = SweepSpec::new(workloads, strategies)
        .with_oversub(oversub)
        .with_seeds(seeds)
        .with_scale(scale)
        .with_cost_model(parse_cost_model(args)?);
    for (level, t) in parse_crash_at(args.get_or("crash-at", ""))? {
        sweep = sweep.with_crash_threshold_at(level, t);
    }

    // one shared trace cache for both lanes; corpus-backed when asked
    let cache = Arc::new(match store {
        Some(s) => TraceCache::with_store(s),
        None => TraceCache::new(),
    });

    // memoized lane: --results stores every artifact-free cell;
    // --resume only asserts the store already has cells to continue from
    let results_store = match args.get("results") {
        Some(dir) => {
            if args.has("resume") && !std::path::Path::new(dir).is_dir() {
                anyhow::bail!(
                    "--resume: results dir {dir} does not exist — nothing to \
                     resume from (drop --resume to start a fresh memoized sweep)"
                );
            }
            Some(Arc::new(ResultStore::open(dir)?))
        }
        None => {
            if args.has("resume") {
                anyhow::bail!(
                    "--resume needs --results DIR (the store holding the \
                     already-computed cells)"
                );
            }
            None
        }
    };

    let csv_path = reports.join("sweep.csv");
    let jsonl_path = reports.join("sweep.jsonl");
    let mut sinks: Vec<Box<dyn SweepSink>> = vec![
        Box::new(ConsoleSink::new()),
        Box::new(CsvSink::to_path(&csv_path)?),
        Box::new(JsonlSink::to_path(&jsonl_path)?),
    ];
    let progress = parse_progress(args)?;

    let t0 = Instant::now();
    let mut runner = SweepRunner::new(&registry)
        .with_threads(threads)
        .with_cache(Arc::clone(&cache))
        .with_progress(progress);
    if let Some(rs) = &results_store {
        runner = runner.with_results(Arc::clone(rs));
    }
    let records = runner.run(&sweep, &ctx, &mut sinks)?;
    let cs = cache.stats();
    println!(
        "{} cells in {:.2?} -> {} + {}",
        records.len(),
        t0.elapsed(),
        csv_path.display(),
        jsonl_path.display()
    );
    println!(
        "trace cache: {} built, {} loaded from corpus, {} persisted, {} shared hits",
        cs.builds, cs.store_loads, cs.store_writes, cs.hits
    );
    if let Some(rs) = &results_store {
        let s = rs.stats();
        println!(
            "results store: skipped {} cells (memoized), {} computed and \
             persisted, {} stale, {} corrupt ({})",
            s.hits,
            s.writes,
            s.stale,
            s.corrupt,
            rs.dir().display()
        );
    }
    let failed = records.iter().filter(|r| r.result.is_err()).count();
    if failed > 0 {
        anyhow::bail!("{failed} cell(s) failed — see the error column");
    }
    Ok(())
}

fn cmd_corpus(args: &Args) -> anyhow::Result<()> {
    let verb = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("list");
    let open_store = || CorpusStore::open(args.get_or("corpus", "corpus"));
    match verb {
        "build" => {
            args.reject_unknown(&["workloads", "scale", "seeds", "corpus"])
                .map_err(anyhow::Error::msg)?;
            let workloads = parse_workloads(args.get_or("workloads", "all"))?;
            let seeds = parse_list::<u64>(args.get_or("seeds", "42"), "seeds")?;
            let scale = Scale {
                factor: args
                    .get_parse("scale", 1u32)
                    .map_err(anyhow::Error::msg)?,
            };
            let cache = TraceCache::with_store(open_store()?);
            for &w in &workloads {
                for &seed in &seeds {
                    let t = cache.get_builtin(w, scale, seed)?;
                    println!(
                        "  {:12} s{} r{:<6} {:>8} accesses, {:>6} pages",
                        w.name(),
                        scale.factor,
                        seed,
                        t.accesses.len(),
                        t.working_set_pages
                    );
                }
            }
            let s = cache.stats();
            println!(
                "corpus build: {} generated, {} already present (dir {})",
                s.builds,
                s.store_loads,
                cache.store().unwrap().dir().display()
            );
            Ok(())
        }
        "import" => {
            args.reject_unknown(&["name", "format", "corpus"])
                .map_err(anyhow::Error::msg)?;
            let file = args.positional.get(1).ok_or_else(|| {
                anyhow::anyhow!(
                    "usage: repro corpus import <file> [--name N] \
                     [--format csv|uvmlog] [--corpus DIR]"
                )
            })?;
            let path = PathBuf::from(file);
            let default_name = path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "imported".to_string());
            let name = args
                .get("name")
                .map(|s| s.to_string())
                .unwrap_or(default_name);
            let is_csv = path
                .extension()
                .and_then(|e| e.to_str())
                .is_some_and(|e| e.eq_ignore_ascii_case("csv"));
            let format =
                args.get_or("format", if is_csv { "csv" } else { "uvmlog" });
            let trace = match format {
                "csv" => corpus::import::csv_trace(&path, &name)?,
                "uvmlog" | "log" | "faultlog" => {
                    corpus::import::uvm_fault_log_trace(&path, &name)?
                }
                other => anyhow::bail!("--format {other}: want csv or uvmlog"),
            };
            let store = open_store()?;
            let (key, out) = store.import(&trace)?;
            println!(
                "imported '{}': {} accesses, {} pages touched, {} kernel phase(s)",
                trace.name,
                trace.accesses.len(),
                trace.touched_pages,
                trace.kernels
            );
            println!("  key  {key}");
            println!("  file {}", out.display());
            println!(
                "run it:  repro sweep --corpus {} --workloads {}",
                store.dir().display(),
                trace.name
            );
            Ok(())
        }
        "export" => {
            args.reject_unknown(&["csv", "key", "corpus"])
                .map_err(anyhow::Error::msg)?;
            let store = open_store()?;
            // stream: header metadata first, then one CSV row per access
            // — the entry's access vector is never materialized
            let (label, mut reader) = match args.get("key") {
                Some(key) => {
                    // store.reader verifies the stored key, so a hash
                    // collision cannot silently export the wrong entry
                    let r = store.reader(key)?.ok_or_else(|| {
                        anyhow::anyhow!(
                            "no corpus entry under key '{key}' in {}",
                            store.dir().display()
                        )
                    })?;
                    (key.to_string(), r)
                }
                None => {
                    let name = args.positional.get(1).ok_or_else(|| {
                        anyhow::anyhow!(
                            "usage: repro corpus export <name> [--csv FILE] \
                             [--key KEY] [--corpus DIR]"
                        )
                    })?;
                    let path = store.find_named_path(name)?.ok_or_else(|| {
                        anyhow::anyhow!(
                            "no corpus entry named '{name}' in {} \
                             (see `repro corpus list`)",
                            store.dir().display()
                        )
                    })?;
                    (name.clone(), uvmio::corpus::TraceReader::open(&path)?)
                }
            };
            let out_path: PathBuf = args
                .get("csv")
                .map(Into::into)
                .unwrap_or_else(|| PathBuf::from(format!("{}.csv", reader.meta().name)));
            use std::io::Write;
            let mut w = std::io::BufWriter::new(
                std::fs::File::create(&out_path).map_err(|e| {
                    anyhow::anyhow!("creating {}: {e}", out_path.display())
                })?,
            );
            writeln!(w, "page,pc,tb,kernel,inst_gap,is_write")?;
            let mut rows = 0u64;
            while let Some(a) = reader.next_access()? {
                writeln!(
                    w,
                    "{},{},{},{},{},{}",
                    a.page, a.pc, a.tb, a.kernel, a.inst_gap, a.is_write as u8
                )?;
                rows += 1;
            }
            w.flush()?;
            println!(
                "exported '{label}' -> {} ({rows} accesses)",
                out_path.display()
            );
            println!(
                "re-import it:  repro corpus import {} --format csv",
                out_path.display()
            );
            Ok(())
        }
        "list" => {
            args.reject_unknown(&["corpus"]).map_err(anyhow::Error::msg)?;
            let store = open_store()?;
            let entries = store.entries()?;
            if entries.is_empty() {
                println!("corpus {} is empty", store.dir().display());
                return Ok(());
            }
            println!(
                "{:<16} {:<9} {:>10} {:>8} {:>7} {:>8}  {}",
                "name", "category", "accesses", "pages", "kernels", "KiB", "key"
            );
            let mut corrupt = 0usize;
            for e in &entries {
                match &e.meta {
                    Ok(m) => println!(
                        "{:<16} {:<9} {:>10} {:>8} {:>7} {:>8}  {}",
                        m.name,
                        // builtin generators carry a workload category
                        // (Table VII classes + llm); imports show '-'
                        Workload::from_name(&m.name)
                            .map(|w| w.category())
                            .unwrap_or("-"),
                        m.accesses,
                        m.working_set_pages,
                        m.kernels,
                        e.bytes / 1024,
                        m.key
                    ),
                    Err(why) => {
                        corrupt += 1;
                        println!(
                            "CORRUPT {} ({} bytes): {why}",
                            e.path.display(),
                            e.bytes
                        );
                    }
                }
            }
            println!(
                "{} entr{} in {}{}",
                entries.len(),
                if entries.len() == 1 { "y" } else { "ies" },
                store.dir().display(),
                if corrupt > 0 {
                    format!(" ({corrupt} corrupt — run `repro corpus gc`)")
                } else {
                    String::new()
                }
            );
            Ok(())
        }
        "gc" => {
            args.reject_unknown(&["corpus"]).map_err(anyhow::Error::msg)?;
            let store = open_store()?;
            let rep = store.gc()?;
            println!(
                "corpus gc: removed {} file(s), reclaimed {} KiB, kept {} entr{}",
                rep.removed_files,
                rep.reclaimed_bytes / 1024,
                rep.kept,
                if rep.kept == 1 { "y" } else { "ies" }
            );
            Ok(())
        }
        other => anyhow::bail!(
            "unknown corpus verb {other:?}; known: build import export list gc"
        ),
    }
}

fn cmd_results(args: &Args) -> anyhow::Result<()> {
    let verb = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("list");
    args.reject_unknown(&["results"]).map_err(anyhow::Error::msg)?;
    let store = ResultStore::open(args.get_or("results", "results"))?;
    match verb {
        "list" => {
            let entries = store.entries()?;
            if entries.is_empty() {
                println!("result store {} is empty", store.dir().display());
                return Ok(());
            }
            println!("{:<18} {:>7} {:>6}  {}", "strategy", "status", "KiB", "key");
            let (mut stale, mut corrupt) = (0usize, 0usize);
            for e in &entries {
                match &e.meta {
                    Ok(m) => {
                        let flag = if m.code_version != store.code_version() {
                            stale += 1;
                            "  [stale]"
                        } else {
                            ""
                        };
                        println!(
                            "{:<18} {:>7} {:>6}  {}{}",
                            m.strategy,
                            m.status,
                            e.bytes / 1024,
                            m.key,
                            flag
                        );
                    }
                    Err(why) => {
                        corrupt += 1;
                        println!(
                            "CORRUPT {} ({} bytes): {why}",
                            e.path.display(),
                            e.bytes
                        );
                    }
                }
            }
            println!(
                "{} entr{} in {} (code version {}){}",
                entries.len(),
                if entries.len() == 1 { "y" } else { "ies" },
                store.dir().display(),
                store.code_version(),
                if stale + corrupt > 0 {
                    format!(
                        " — {stale} stale, {corrupt} corrupt; run \
                         `repro results gc`"
                    )
                } else {
                    String::new()
                }
            );
            Ok(())
        }
        "gc" => {
            let rep = store.gc()?;
            println!(
                "results gc: removed {} file(s), reclaimed {} KiB, kept {} entr{}",
                rep.removed_files,
                rep.reclaimed_bytes / 1024,
                rep.kept,
                if rep.kept == 1 { "y" } else { "ies" }
            );
            Ok(())
        }
        other => {
            anyhow::bail!("unknown results verb {other:?}; known: list gc")
        }
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["addr", "stdin", "corpus", "results", "threads"])
        .map_err(anyhow::Error::msg)?;
    let cache = Arc::new(match args.get("corpus") {
        Some(dir) => TraceCache::with_store(CorpusStore::open(dir)?),
        None => TraceCache::new(),
    });
    let mut shared = ServeShared::new(cache);
    // a second handle on the same directory: selectors like
    // `corpus:name` resolve against it while the cache above persists
    if let Some(dir) = args.get("corpus") {
        shared.corpus = Some(CorpusStore::open(dir)?);
    }
    if let Some(dir) = args.get("results") {
        shared.results = Some(Arc::new(ResultStore::open(dir)?));
    }
    shared.threads =
        args.get_parse("threads", 0usize).map_err(anyhow::Error::msg)?;
    if args.has("stdin") {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        return serve_stdin(&shared, stdin.lock(), stdout.lock());
    }
    serve_tcp(args.get_or("addr", "127.0.0.1:7077"), shared)
}

fn cmd_accuracy(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&[
        "workload", "method", "scale", "seed", "artifacts", "predictor",
    ])
    .map_err(anyhow::Error::msg)?;
    let opts = opts_from(args)?;
    let w = parse_workload(args)?;
    let method = args.get_or("method", "online").to_string();
    let (model, dims) = match opts.predictor {
        PredictorKind::Native => {
            let m: Arc<dyn ModelBackend> =
                Arc::new(NativeModel::for_model("predictor")?);
            (m, native_dims())
        }
        other => {
            other.ensure_available()?;
            let runtime = Runtime::new(&opts.artifacts_dir)?;
            let m: Arc<dyn ModelBackend> =
                Arc::new(runtime.model("predictor")?);
            (m, uvmio::coordinator::feat_dims(&runtime))
        }
    };
    let trace = w.generate(opts.scale, opts.seed);
    let (samples, vocab) = samples_from_trace(&trace, dims);
    println!("workload: {} ({} samples, {} delta classes)",
             trace.name, samples.len(), vocab.assigned());
    let report = match method.as_str() {
        "online" => online_accuracy(&model, &dims, &samples, &TrainOpts::default(), None)?,
        "ours" => online_accuracy(&model, &dims, &samples, &TrainOpts::ours(), None)?,
        "offline" => offline_accuracy(&model, &dims, &samples, &TrainOpts::default())?,
        other => anyhow::bail!("unknown method {other}"),
    };
    println!("method  : {}", report.method);
    println!("top-1   : {:.3} over {} evaluations", report.top1, report.evaluated);
    println!("training: {} steps, {} model(s)", report.train_steps, report.patterns_used);
    Ok(())
}

/// `repro lint [--deny] [--write-baseline] [PATH]` — the
/// determinism/conservation static-analysis pass over a crate tree
/// (default: the crate this binary was built from, or `rust/` when run
/// from the workspace root).
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["deny", "write-baseline"])
        .map_err(anyhow::Error::msg)?;
    // boolean flags swallow a following bare token as their value, so
    // accept both `lint rust --deny` and `lint --deny rust`
    let mut root: Option<String> = args.positional.first().cloned();
    for flag in ["deny", "write-baseline"] {
        if let Some(v) = args.get(flag) {
            if v != uvmio::util::cli::FLAG_SET {
                root = Some(v.to_string());
            }
        }
    }
    let root = root.unwrap_or_else(|| {
        if std::path::Path::new("rust/src").is_dir() {
            "rust".into()
        } else {
            ".".into()
        }
    });
    let root = std::path::Path::new(&root);

    if args.has("write-baseline") {
        let rendered = uvmio::analysis::write_baseline(root)?;
        eprintln!(
            "wrote {}",
            root.join(uvmio::analysis::BASELINE_FILE).display()
        );
        print!("{rendered}");
        return Ok(());
    }

    let report = uvmio::analysis::run_lint(root)?;
    for d in &report.violations {
        println!("{d}");
    }
    for n in &report.notes {
        println!("note: {n}");
    }
    println!(
        "lint: {} file(s) checked, {} violation(s), {} note(s)",
        report.files,
        report.violations.len(),
        report.notes.len()
    );
    if args.has("deny") && !report.clean() {
        anyhow::bail!(
            "lint --deny: {} violation(s)",
            report.violations.len()
        );
    }
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let registry = StrategyRegistry::builtin();
    println!("strategies:");
    for name in registry.names() {
        let s = registry.get(name)?;
        println!(
            "  {:14} {:16} {}",
            s.name,
            s.display,
            if s.needs_artifacts { "[needs artifacts]" } else { "" }
        );
    }
    println!("workloads:");
    for w in Workload::ALL.into_iter().chain(Workload::LLM) {
        let t = w.generate(Scale::default(), 42);
        println!(
            "  {:12} {:>6} pages  {:>7} accesses  {} kernels  [{}]",
            w.name(),
            t.working_set_pages,
            t.accesses.len(),
            t.kernels,
            w.category()
        );
    }
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts ({}):", dir.display());
            for (name, e) in &m.models {
                println!(
                    "  {:10} {:>7} params  fwd/train/init present  ({:.2} MB params)",
                    name, e.param_count, e.params_mb
                );
            }
        }
        Err(_) => println!("artifacts: not built (run `make artifacts`)"),
    }
    Ok(())
}
